//! Minimal JSON emitter **and parser** for results/wisdom files.
//!
//! The emitter covers figure series, bench reports and experiment
//! records; the parser was added for the `service` layer's wisdom store
//! (`results/wisdom.json` must survive a server restart). Both live here
//! because the offline vendor set has no serde.

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (Vec keeps output stable for diffs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Add/overwrite a field on an object (panics on non-objects —
    /// builder misuse is a programming error).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => {
                let val = val.into();
                if let Some(f) = fields.iter_mut().find(|(k, _)| k == key) {
                    f.1 = val;
                } else {
                    fields.push((key.to_string(), val));
                }
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Field lookup on objects (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric view: `Int` and `Num` both read as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Non-negative integer view. Whole floats are accepted only up to
    /// 2^53 (f64's exact-integer range) — beyond that the value could
    /// not faithfully represent an integer, and a saturating `as` cast
    /// would silently return usize::MAX for garbage like 1e300.
    pub fn as_usize(&self) -> Option<usize> {
        const F64_EXACT_MAX: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Json::Int(i) if *i >= 0 => Some(*i as usize),
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= F64_EXACT_MAX => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document (the inverse of [`Json::to_string`] /
    /// [`Json::to_pretty`]). Integer literals (no `.`/exponent) become
    /// [`Json::Int`]; everything else numeric becomes [`Json::Num`].
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    // Debug formatting is shortest-roundtrip AND always
                    // keeps a decimal point or exponent ("2.0", not "2"),
                    // so parse() reads a Num back as Num, never Int
                    let _ = write!(out, "{x:?}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    out.push_str(nl);
                    out.push_str(&pad);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    out.push_str(nl);
                    out.push_str(&pad);
                }
                out.push('}');
            }
        }
    }
}

/// Recursive-descent JSON parser (RFC 8259 subset: no duplicate-key
/// policing; surrogate pairs in `\u` escapes are combined).
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            None => Err("unexpected end of input".to_string()),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected `{}` at byte {}", c as char, self.pos)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| format!("invalid utf-8 near byte {start}"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: require \uXXXX low half
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("invalid low surrogate".to_string());
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| format!("invalid codepoint {cp:#x}"))?,
                            );
                        }
                        c => return Err(format!("bad escape `\\{}`", c as char)),
                    }
                }
                _ => return Err("unterminated string".to_string()),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_int = true;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_int = false;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_int {
            if let Ok(i) = s.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{s}` at byte {start}"))
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(3i64).to_string(), "3");
        assert_eq!(Json::from(1.5).to_string(), "1.5");
        assert_eq!(Json::from("a\"b\n").to_string(), r#""a\"b\n""#);
    }

    #[test]
    fn whole_floats_keep_decimal_point() {
        assert_eq!(Json::from(2.0).to_string(), "2.0");
        assert_eq!(Json::parse("2.0").unwrap(), Json::Num(2.0));
        // Num round-trips as Num even when whole (parse is a true inverse)
        let j = Json::from(100.0);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn object_builder_ordered_and_overwrites() {
        let j = Json::obj().set("b", 1i64).set("a", 2i64).set("b", 3i64);
        assert_eq!(j.to_string(), r#"{"b":3,"a":2}"#);
    }

    #[test]
    fn arrays_nest() {
        let j = Json::from(vec![1i64, 2, 3]);
        assert_eq!(j.to_string(), "[1,2,3]");
        let o = Json::obj().set("xs", j);
        assert_eq!(o.to_string(), r#"{"xs":[1,2,3]}"#);
    }

    #[test]
    fn pretty_roundtrips_structure() {
        let j = Json::obj()
            .set("name", "fig15")
            .set("series", Json::from(vec![1.0, 2.0]));
        let p = j.to_pretty();
        assert!(p.contains("\n  \"name\": \"fig15\""), "{p}");
    }

    #[test]
    fn control_chars_escaped() {
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(Json::parse("1.5").unwrap(), Json::Num(1.5));
        assert_eq!(Json::parse("2e3").unwrap(), Json::Num(2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_structures() {
        let j = Json::parse(r#"{"a":[1,2.5,"x"],"b":{"c":null}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("b").unwrap().get("c"), Some(&Json::Null));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
    }

    #[test]
    fn parse_string_escapes() {
        assert_eq!(Json::parse(r#""a\"b\n\t\\""#).unwrap(), Json::from("a\"b\n\t\\"));
        assert_eq!(Json::parse(r#""\u0041""#).unwrap(), Json::from("A"));
        // surrogate pair: U+1F600
        assert_eq!(Json::parse(r#""\ud83d\ude00""#).unwrap(), Json::from("\u{1F600}"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn emit_parse_roundtrip() {
        let j = Json::obj()
            .set("name", "wisdom")
            .set("pi", 3.141592653589793)
            .set("count", 64i64)
            .set("ok", true)
            .set("none", Json::Null)
            .set("xs", Json::from(vec![1i64, 2, 3]))
            .set("nested", Json::obj().set("speeds", Json::from(vec![1.25, 2.5])));
        let compact = Json::parse(&j.to_string()).unwrap();
        let pretty = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(compact, j);
        assert_eq!(pretty, j);
    }

    #[test]
    fn accessors() {
        let j = Json::obj().set("n", 8i64).set("x", 2.0).set("s", "v");
        assert_eq!(j.get("n").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("x").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("x").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("s").unwrap().as_str(), Some("v"));
        assert_eq!(j.get("missing"), None);
        assert_eq!(Json::Null.get("n"), None);
        // out-of-exact-range floats are rejected, not saturated
        assert_eq!(Json::Num(1e300).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }
}
