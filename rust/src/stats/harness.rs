//! Bench harness built on the paper's measurement methodology.
//!
//! `cargo bench` targets (`rust/benches/*.rs`, `harness = false`) use this
//! instead of criterion (not in the offline vendor set): each benchmark is
//! warmed up, then measured with [`mean_using_ttest`] until the 95% CI is
//! tight, and reported with mean/CI/min plus an optional MFLOPs column
//! computed with the paper's speed formula.

use std::path::Path;
use std::time::Instant;

use crate::stats::{mean_using_ttest, StopReason, TtestMean, TtestPolicy};
use crate::util::json::Json;

/// One benchmark's outcome.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub ci_half_width_s: f64,
    pub reps: usize,
    pub stop: StopReason,
    /// Optional work term: complex-FLOP count for MFLOPs reporting.
    pub flops: Option<f64>,
}

impl BenchResult {
    pub fn mflops(&self) -> Option<f64> {
        self.flops.map(|f| f / self.mean_s / 1e6)
    }
}

/// A suite of benchmarks sharing a policy; prints a criterion-like report
/// and can dump JSON for EXPERIMENTS.md bookkeeping.
pub struct BenchSuite {
    pub name: String,
    pub policy: TtestPolicy,
    pub warmup_iters: usize,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        // Bench policy: tighter than quick(), bounded for CI wall-time.
        let policy = TtestPolicy {
            min_reps: 10,
            max_reps: 200,
            max_time_s: 20.0,
            cl: 0.95,
            eps: 0.025,
        };
        BenchSuite { name: name.to_string(), policy, warmup_iters: 3, results: Vec::new() }
    }

    /// Override policy (e.g. fast smoke under `HCLFFT_BENCH_FAST=1`).
    pub fn with_policy(mut self, policy: TtestPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Honour the env knob used by CI to keep bench wall time bounded.
    pub fn from_env(name: &str) -> Self {
        let mut suite = Self::new(name);
        if std::env::var("HCLFFT_BENCH_FAST").is_ok() {
            // even the smoke policy keeps >= 5 reps so every reported
            // mean carries a t-test CI (single-shot ratios rot — see
            // the SNIPPETS.md consensus cautionary tale)
            suite.policy = TtestPolicy { min_reps: 5, max_reps: 10, max_time_s: 2.0, cl: 0.95, eps: 0.1 };
            suite.warmup_iters = 1;
        }
        suite
    }

    /// Benchmark `f`, timing one call per observation.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_flops(name, None, &mut f)
    }

    /// Benchmark with a known per-call complex-FLOP count (for MFLOPs).
    pub fn bench_flops<F: FnMut()>(&mut self, name: &str, flops: f64, mut f: F) -> &BenchResult {
        self.bench_with_flops(name, Some(flops), &mut f)
    }

    fn bench_with_flops(&mut self, name: &str, flops: Option<f64>, f: &mut dyn FnMut()) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let m: TtestMean = mean_using_ttest(&self.policy, || {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        });
        let r = BenchResult {
            name: name.to_string(),
            mean_s: m.mean,
            ci_half_width_s: m.ci_half_width,
            reps: m.reps,
            stop: m.stop,
            flops,
        };
        println!("{}", render_line(&self.name, &r));
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Render the final report table.
    pub fn report(&self) -> String {
        let mut out = format!("\n== bench suite: {} ==\n", self.name);
        for r in &self.results {
            out.push_str(&render_line(&self.name, r));
            out.push('\n');
        }
        out
    }

    /// Dump machine-readable results.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        let arr: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut o = Json::obj()
                    .set("name", r.name.as_str())
                    .set("mean_s", r.mean_s)
                    .set("ci_half_width_s", r.ci_half_width_s)
                    .set("reps", r.reps);
                if let Some(mf) = r.mflops() {
                    o = o.set("mflops", mf);
                }
                o
            })
            .collect();
        let doc = Json::obj()
            .set("suite", self.name.as_str())
            .set("results", Json::Arr(arr));
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, doc.to_pretty())
    }
}

fn render_line(suite: &str, r: &BenchResult) -> String {
    let unit = scale_time(r.mean_s);
    let mut line = format!(
        "{suite}/{name:<40} {mean:>10} ± {ci:>8}  ({reps} reps)",
        name = r.name,
        mean = unit.fmt(r.mean_s),
        ci = unit.fmt(r.ci_half_width_s),
        reps = r.reps,
    );
    if let Some(mf) = r.mflops() {
        line.push_str(&format!("  {mf:>10.1} MFLOPs"));
    }
    if r.stop == StopReason::MaxTimeExceeded {
        line.push_str("  [time-capped]");
    }
    line
}

/// Pick a human time unit for a mean value.
struct TimeUnit {
    factor: f64,
    suffix: &'static str,
}

impl TimeUnit {
    fn fmt(&self, s: f64) -> String {
        format!("{:.3}{}", s * self.factor, self.suffix)
    }
}

fn scale_time(s: f64) -> TimeUnit {
    if s >= 1.0 {
        TimeUnit { factor: 1.0, suffix: "s" }
    } else if s >= 1e-3 {
        TimeUnit { factor: 1e3, suffix: "ms" }
    } else if s >= 1e-6 {
        TimeUnit { factor: 1e6, suffix: "µs" }
    } else {
        TimeUnit { factor: 1e9, suffix: "ns" }
    }
}

/// The paper's speed formula inverted: complex-FLOP count of `x` row FFTs
/// of length `y` — `2.5 · x · y · log2(y)` (used for MFLOPs columns so our
/// numbers are comparable with the paper's plots).
pub fn fft_flops(x: usize, y: usize) -> f64 {
    2.5 * x as f64 * y as f64 * (y as f64).log2()
}

/// Complex-FLOP count of a full NxN 2D-DFT (both phases).
pub fn fft2d_flops(n: usize) -> f64 {
    2.0 * fft_flops(n, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut suite = BenchSuite::new("test").with_policy(TtestPolicy::quick());
        suite.warmup_iters = 1;
        let r = suite.bench("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.mean_s >= 0.0);
        assert_eq!(suite.results.len(), 1);
        assert!(suite.report().contains("noop"));
    }

    #[test]
    fn mflops_formula() {
        // 2.5 * 4 * 8 * 3 = 240
        assert_eq!(fft_flops(4, 8), 240.0);
        assert_eq!(fft2d_flops(8), 2.0 * fft_flops(8, 8));
        let r = BenchResult {
            name: "x".into(),
            mean_s: 0.001,
            ci_half_width_s: 0.0,
            reps: 5,
            stop: StopReason::PrecisionReached,
            flops: Some(240.0),
        };
        assert!((r.mflops().unwrap() - 0.24).abs() < 1e-12);
    }

    #[test]
    fn time_unit_scaling() {
        assert_eq!(scale_time(2.0).suffix, "s");
        assert_eq!(scale_time(2e-3).suffix, "ms");
        assert_eq!(scale_time(2e-6).suffix, "µs");
        assert_eq!(scale_time(2e-10).suffix, "ns");
    }

    #[test]
    fn json_dump_writes() {
        let mut suite = BenchSuite::new("jsontest").with_policy(TtestPolicy::quick());
        suite.warmup_iters = 0;
        suite.bench_flops("f", 100.0, || std::hint::black_box(()));
        let path = std::env::temp_dir().join("hclfft_bench_test/out.json");
        suite.write_json(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"suite\": \"jsontest\""));
        assert!(s.contains("mflops"));
    }
}
