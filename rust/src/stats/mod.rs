//! Statistical measurement methodology (paper §V-A, Algorithm 8).
//!
//! The paper's experimental rigor is itself a contribution worth
//! reproducing: every data point of a speed function is the sample mean of
//! repeated executions, accepted only once the Student's-t 95% confidence
//! interval is within 2.5% of the mean. [`ttest`] implements the
//! distribution machinery from scratch (no GSL here), [`mean_using_ttest`]
//! is Algorithm 8, and [`harness`] builds the `cargo bench` harness on top
//! of it (the vendored crate set has no criterion — and the paper's own
//! methodology is the more faithful harness anyway).

pub mod harness;
pub mod ttest;

use std::time::Instant;

/// Descriptive statistics over a sample.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute n/mean/sd/min/max of a sample (sd is the sample standard
/// deviation, n-1 denominator, as in `gsl_stats_sd`).
pub fn summary(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    Summary {
        n,
        mean,
        sd: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Stopping policy for [`mean_using_ttest`] — the paper's Algorithm 8
/// inputs, with the per-problem-size repetition classes of §V-A.
#[derive(Clone, Copy, Debug)]
pub struct TtestPolicy {
    pub min_reps: usize,
    pub max_reps: usize,
    /// Max total elapsed seconds (paper: 3600).
    pub max_time_s: f64,
    /// Confidence level (paper: 0.95).
    pub cl: f64,
    /// Required relative precision (paper: 0.025).
    pub eps: f64,
}

impl TtestPolicy {
    /// Paper §V-A repetition classes by 1D problem size `n`:
    /// small (32..=1024): 10000/100000, medium (..=5120): 100/1000,
    /// large (>5120): 5/50. We scale the rep counts down by `scale` for
    /// CI-speed runs (scale=1 reproduces the paper's numbers).
    pub fn for_problem_size(n: usize, scale: usize) -> Self {
        let scale = scale.max(1);
        let (min_reps, max_reps) = if n <= 1024 {
            (10_000 / scale, 100_000 / scale)
        } else if n <= 5120 {
            (100 / scale, 1000 / scale)
        } else {
            (5, 50)
        };
        TtestPolicy {
            min_reps: min_reps.max(3),
            max_reps: max_reps.max(5),
            max_time_s: 3600.0,
            cl: 0.95,
            eps: 0.025,
        }
    }

    /// A fast policy for unit tests and smoke benches.
    pub fn quick() -> Self {
        TtestPolicy { min_reps: 5, max_reps: 30, max_time_s: 10.0, cl: 0.95, eps: 0.05 }
    }
}

/// Why the measurement loop stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Confidence interval within eps of the mean (the desired outcome —
    /// the paper observed this always fired first).
    PrecisionReached,
    MaxRepsExceeded,
    MaxTimeExceeded,
}

/// Result of a [`mean_using_ttest`] measurement.
#[derive(Clone, Debug)]
pub struct TtestMean {
    pub mean: f64,
    /// Half-width of the CI actually achieved (absolute, same unit as mean).
    pub ci_half_width: f64,
    /// Relative precision achieved (`epsOut` of Algorithm 8).
    pub eps_out: f64,
    pub reps: usize,
    pub elapsed_s: f64,
    pub stop: StopReason,
    pub samples: Vec<f64>,
}

/// Algorithm 8 (`MeanUsingTtest`): repeatedly run `measure` (which returns
/// one observation, e.g. seconds of one application execution) until the
/// sample mean lies within `policy.eps` relative precision at confidence
/// `policy.cl`, or a rep/time cap fires.
pub fn mean_using_ttest<F: FnMut() -> f64>(policy: &TtestPolicy, mut measure: F) -> TtestMean {
    let started = Instant::now();
    let mut samples: Vec<f64> = Vec::with_capacity(policy.min_reps.max(16));
    let mut sum = 0.0f64;
    let mut stop = StopReason::MaxRepsExceeded;
    let mut ci_half_width = f64::INFINITY;

    while samples.len() < policy.max_reps {
        let obs = measure();
        sum += obs;
        samples.push(obs);
        let reps = samples.len();
        if reps > policy.min_reps && reps > 1 {
            let s = summary(&samples);
            // clOut = t_{cl, reps-1} * sd / sqrt(reps)   (Algorithm 8, L12)
            let t = ttest::t_inv_cdf(policy.cl, (reps - 1) as f64);
            ci_half_width = t * s.sd / (reps as f64).sqrt();
            // stop if clOut * reps / sum < eps            (L13)
            if ci_half_width * reps as f64 / sum < policy.eps {
                stop = StopReason::PrecisionReached;
                break;
            }
            if started.elapsed().as_secs_f64() > policy.max_time_s {
                stop = StopReason::MaxTimeExceeded;
                break;
            }
        }
    }

    let reps = samples.len();
    let mean = sum / reps as f64;
    TtestMean {
        mean,
        ci_half_width: if ci_half_width.is_finite() { ci_half_width } else { 0.0 },
        eps_out: if sum > 0.0 { ci_half_width * reps as f64 / sum } else { 0.0 },
        reps,
        elapsed_s: started.elapsed().as_secs_f64(),
        stop,
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn summary_basics() {
        let s = summary(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.sd - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty_and_single() {
        assert_eq!(summary(&[]).n, 0);
        let s = summary(&[7.0]);
        assert_eq!((s.mean, s.sd), (7.0, 0.0));
    }

    #[test]
    fn ttest_loop_converges_on_low_noise() {
        let mut rng = Xoshiro256::seeded(1);
        let policy = TtestPolicy { min_reps: 5, max_reps: 10_000, max_time_s: 5.0, cl: 0.95, eps: 0.025 };
        let r = mean_using_ttest(&policy, || 1.0 + 0.01 * rng.next_gaussian());
        assert_eq!(r.stop, StopReason::PrecisionReached);
        assert!((r.mean - 1.0).abs() < 0.01, "mean {}", r.mean);
        assert!(r.eps_out < 0.025);
        assert!(r.reps >= 6);
    }

    #[test]
    fn ttest_loop_needs_more_reps_for_noisier_data() {
        let policy = TtestPolicy { min_reps: 5, max_reps: 100_000, max_time_s: 10.0, cl: 0.95, eps: 0.025 };
        let mut quiet_rng = Xoshiro256::seeded(2);
        let quiet = mean_using_ttest(&policy, || 1.0 + 0.01 * quiet_rng.next_gaussian());
        let mut noisy_rng = Xoshiro256::seeded(2);
        let noisy = mean_using_ttest(&policy, || 1.0 + 0.2 * noisy_rng.next_gaussian());
        assert!(noisy.reps > quiet.reps, "noisy {} quiet {}", noisy.reps, quiet.reps);
    }

    #[test]
    fn ttest_loop_caps_reps() {
        let mut rng = Xoshiro256::seeded(3);
        let policy = TtestPolicy { min_reps: 2, max_reps: 10, max_time_s: 5.0, cl: 0.95, eps: 1e-9 };
        let r = mean_using_ttest(&policy, || 1.0 + rng.next_gaussian().abs());
        assert_eq!(r.reps, 10);
        assert_eq!(r.stop, StopReason::MaxRepsExceeded);
    }

    #[test]
    fn policy_classes_match_paper() {
        let small = TtestPolicy::for_problem_size(512, 1);
        assert_eq!((small.min_reps, small.max_reps), (10_000, 100_000));
        let medium = TtestPolicy::for_problem_size(4096, 1);
        assert_eq!((medium.min_reps, medium.max_reps), (100, 1000));
        let large = TtestPolicy::for_problem_size(30_000, 1);
        assert_eq!((large.min_reps, large.max_reps), (5, 50));
        assert_eq!(small.cl, 0.95);
        assert_eq!(small.eps, 0.025);
        assert_eq!(small.max_time_s, 3600.0);
    }
}
