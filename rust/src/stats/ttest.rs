//! Student's t distribution from scratch (the paper uses
//! `gsl_cdf_tdist_Pinv`; no GSL in the vendor set, so: Lanczos log-gamma,
//! regularized incomplete beta via Lentz's continued fraction, t CDF, and
//! quantile by monotone bisection).

/// Lanczos approximation of ln Γ(x), x > 0. |err| < 2e-10 over our range.
pub fn ln_gamma(x: f64) -> f64 {
    // g = 7, n = 9 coefficients (standard Lanczos table)
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized incomplete beta I_x(a, b) via Lentz's continued fraction.
pub fn beta_inc(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "beta_inc: a,b must be positive");
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    // symmetry pick for fast CF convergence (<= so the boundary case
    // x = (a+1)/(a+b+2) with a = b cannot recurse forever)
    if x <= (a + 1.0) / (a + b + 2.0) {
        (ln_front.exp() * beta_cf(a, b, x)) / a
    } else {
        1.0 - beta_inc(b, a, 1.0 - x)
    }
}

/// Continued fraction for the incomplete beta (Numerical Recipes betacf).
fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 3e-15;
    const FPMIN: f64 = 1e-300;

    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // even step
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // odd step
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// CDF of Student's t with `df` degrees of freedom.
pub fn t_cdf(x: f64, df: f64) -> f64 {
    assert!(df > 0.0, "t_cdf: df must be positive");
    if x == 0.0 {
        return 0.5;
    }
    let p = 0.5 * beta_inc(0.5 * df, 0.5, df / (df + x * x));
    if x > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Inverse CDF (quantile) of Student's t: returns x with CDF(x) = p.
/// Equivalent of `gsl_cdf_tdist_Pinv(p, df)`.
pub fn t_inv_cdf(p: f64, df: f64) -> f64 {
    assert!((0.0..1.0).contains(&p) && p > 0.0, "t_inv_cdf: p in (0,1)");
    assert!(df > 0.0);
    if (p - 0.5).abs() < 1e-16 {
        return 0.0;
    }
    // CDF is strictly increasing; bisect on a bracketing interval.
    let (mut lo, mut hi) = if p > 0.5 { (0.0, 1e3) } else { (-1e3, 0.0) };
    // widen if necessary (tiny df has fat tails)
    while t_cdf(hi, df) < p {
        hi *= 2.0;
        if hi > 1e12 {
            break;
        }
    }
    while t_cdf(lo, df) > p {
        lo *= 2.0;
        if lo < -1e12 {
            break;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if t_cdf(mid, df) < p {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo < 1e-12 * (1.0 + hi.abs()) {
            break;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(3)=2, Γ(0.5)=sqrt(pi)
        assert!(ln_gamma(1.0).abs() < 1e-10);
        assert!(ln_gamma(2.0).abs() < 1e-10);
        assert!((ln_gamma(3.0) - 2.0f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
        // Γ(10) = 362880
        assert!((ln_gamma(10.0) - 362880.0f64.ln()).abs() < 1e-8);
    }

    #[test]
    fn beta_inc_endpoints_and_symmetry() {
        assert_eq!(beta_inc(2.0, 3.0, 0.0), 0.0);
        assert_eq!(beta_inc(2.0, 3.0, 1.0), 1.0);
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        for &(a, b, x) in &[(2.0, 3.0, 0.3), (0.5, 0.5, 0.7), (5.0, 1.5, 0.45)] {
            let lhs = beta_inc(a, b, x);
            let rhs = 1.0 - beta_inc(b, a, 1.0 - x);
            assert!((lhs - rhs).abs() < 1e-12, "{a} {b} {x}");
        }
        // I_x(1,1) = x (uniform)
        assert!((beta_inc(1.0, 1.0, 0.42) - 0.42).abs() < 1e-12);
    }

    #[test]
    fn t_cdf_reference_values() {
        // t distribution with df=1 is Cauchy: CDF(1) = 3/4
        assert!((t_cdf(1.0, 1.0) - 0.75).abs() < 1e-10);
        // symmetry
        assert!((t_cdf(-1.3, 7.0) + t_cdf(1.3, 7.0) - 1.0).abs() < 1e-12);
        // large df approaches normal: CDF(1.96, 1e6) ~ 0.975
        assert!((t_cdf(1.96, 1e6) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn t_quantiles_match_tables() {
        // classic two-sided 95% critical values (one-sided p=0.975)
        let cases = [
            (0.975, 1.0, 12.706),
            (0.975, 2.0, 4.303),
            (0.975, 5.0, 2.571),
            (0.975, 10.0, 2.228),
            (0.975, 30.0, 2.042),
            (0.95, 10.0, 1.812),
            (0.99, 10.0, 2.764),
        ];
        for (p, df, expect) in cases {
            let got = t_inv_cdf(p, df);
            assert!((got - expect).abs() < 2e-3, "p={p} df={df}: got {got}, want {expect}");
        }
    }

    #[test]
    fn t_inv_is_inverse_of_cdf() {
        for &df in &[1.0, 3.0, 9.0, 49.0] {
            for &p in &[0.05, 0.2, 0.5, 0.8, 0.95, 0.975] {
                let x = t_inv_cdf(p, df);
                assert!((t_cdf(x, df) - p).abs() < 1e-9, "df={df} p={p}");
            }
        }
    }

    #[test]
    fn negative_quantiles_symmetric() {
        let a = t_inv_cdf(0.025, 10.0);
        let b = t_inv_cdf(0.975, 10.0);
        assert!((a + b).abs() < 1e-9);
    }
}
