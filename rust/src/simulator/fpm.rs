//! Simulated FPM surfaces and sections (Figures 9-14).
//!
//! Wraps [`PackageModel::group_speed`] into the coordinator's
//! [`SpeedFunction`]/[`Curve`] types: full surfaces for Figures 13-14,
//! lazy plane/column sections for the partitioning and padding steps of
//! the virtual campaign (building a full surface per campaign size would
//! be wasteful — sections are O(grid) each).

use crate::coordinator::group::GroupConfig;
use crate::model::{Curve, SpeedFunction};
use crate::simulator::packages::PackageModel;
use crate::simulator::Package;

/// The paper's FPM grid step (problem sizes are multiples of 128 in the
/// speed-function construction, §V-B).
pub const GRID_STEP: usize = 128;

/// Max surface coordinate (paper: 64000).
pub const GRID_MAX: usize = 64_000;

/// Memory cap: points with x·y above this are "not built due to main
/// memory constraint" (§V-B). 64000·24704 complex doubles ≈ 24 GiB.
pub const MEM_CAP_XY: u128 = 64_000 * 24_704;

/// A simulated virtual testbed for one package and group configuration.
#[derive(Clone, Debug)]
pub struct SimTestbed {
    pub model: PackageModel,
    pub cfg: GroupConfig,
}

impl SimTestbed {
    pub fn new(package: Package, cfg: GroupConfig) -> Self {
        SimTestbed { model: PackageModel::new(package), cfg }
    }

    /// With the package's paper-best (p, t). For the planning and
    /// scheduling layers, wrap the testbed in
    /// [`crate::model::SimModel`] — they consume the unified
    /// [`crate::model::PerfModel`] trait, never the testbed directly.
    pub fn paper_best(package: Package) -> Self {
        Self::new(package, package.best_groups())
    }

    /// Plane section y = n for group `g` (1-based): speed vs x on the
    /// 128-grid up to n, memory-capped (PFFT-FPM Step 1a).
    pub fn plane_section(&self, g: usize, n: usize) -> Curve {
        let mut xs = Vec::new();
        let mut speeds = Vec::new();
        let mut x = GRID_STEP;
        while x <= n {
            if (x as u128) * (n as u128) <= MEM_CAP_XY {
                xs.push(x);
                speeds.push(self.model.group_speed(x, n, g, self.cfg.p, self.cfg.t));
            }
            x += GRID_STEP;
        }
        Curve::new(xs, speeds)
    }

    /// All p plane sections at y = n.
    pub fn plane_sections(&self, n: usize) -> Vec<Curve> {
        (1..=self.cfg.p).map(|g| self.plane_section(g, n)).collect()
    }

    /// Column section x = d for group `g`: speed vs y over
    /// (n, n + window] on the 128-grid (PAD Step 2 candidates), starting
    /// at y = n itself.
    pub fn column_section(&self, g: usize, d: usize, n: usize, window: usize) -> Curve {
        let mut ys = Vec::new();
        let mut speeds = Vec::new();
        let mut y = n;
        let cap = n.saturating_add(window).min(GRID_MAX);
        while y <= cap {
            if (d as u128) * (y as u128) <= MEM_CAP_XY || y == n {
                ys.push(y);
                speeds.push(self.model.group_speed(d, y, g, self.cfg.p, self.cfg.t));
            }
            y += GRID_STEP;
        }
        Curve::new(ys, speeds)
    }

    /// Full FPM surface for group `g` on a decimated grid (Figures 13-14;
    /// `decimate` thins the 128-grid to keep the dump small).
    pub fn full_surface(&self, g: usize, decimate: usize) -> SpeedFunction {
        let step = GRID_STEP * decimate.max(1);
        let coords: Vec<usize> = (1..).map(|k| k * step).take_while(|&v| v <= GRID_MAX).collect();
        SpeedFunction::from_fn(
            &format!("{}-group{}-p{}t{}", self.model.package.name(), g, self.cfg.p, self.cfg.t),
            coords.clone(),
            coords,
            |x, y| {
                if (x as u128) * (y as u128) <= MEM_CAP_XY {
                    Some(self.model.group_speed(x, y, g, self.cfg.p, self.cfg.t))
                } else {
                    None
                }
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plane_section_grid() {
        let tb = SimTestbed::paper_best(Package::Mkl);
        let c = tb.plane_section(1, 24_704);
        assert_eq!(c.xs[0], 128);
        assert_eq!(*c.xs.last().unwrap(), 24_704);
        assert_eq!(c.xs.len(), 24_704 / 128);
        assert!(c.speeds.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn sections_differ_between_groups() {
        // heterogeneity (NUMA asymmetry + per-group drops) must show up,
        // otherwise HPOPTA never fires
        let tb = SimTestbed::paper_best(Package::Mkl);
        let c1 = tb.plane_section(1, 24_704);
        let c2 = tb.plane_section(2, 24_704);
        let diff = c1
            .speeds
            .iter()
            .zip(&c2.speeds)
            .filter(|(a, b)| ((**a - **b).abs() / **b) > 0.05)
            .count();
        assert!(diff > c1.len() / 20, "only {diff} differing points");
    }

    #[test]
    fn memory_cap_applied() {
        let tb = SimTestbed::paper_best(Package::Fftw3);
        let c = tb.plane_section(1, 63_936);
        // x grid must stop before the cap
        let max_x = *c.xs.last().unwrap();
        assert!((max_x as u128) * 63_936 <= MEM_CAP_XY);
        assert!(max_x < 63_936);
    }

    #[test]
    fn column_section_window() {
        let tb = SimTestbed::paper_best(Package::Mkl);
        let c = tb.column_section(1, 11_648, 24_704, 2048);
        assert_eq!(c.xs[0], 24_704);
        assert!(*c.xs.last().unwrap() <= 24_704 + 2048);
        assert!(c.len() > 10);
    }

    #[test]
    fn full_surface_has_gaps_at_cap() {
        let tb = SimTestbed::paper_best(Package::Fftw3);
        let s = tb.full_surface(1, 64); // coarse 8192-grid
        assert!(s.measured_points() > 0);
        // the far corner must be missing (memory cap)
        assert_eq!(s.get(57_344, 57_344), None);
    }
}
