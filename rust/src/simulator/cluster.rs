//! Cluster extension — the paper's second future-work item (§VII:
//! "extensions of them for homogeneous and heterogeneous clusters of
//! multicore nodes").
//!
//! A virtual cluster of `k` multicore nodes, each a full copy of the
//! single-node testbed (optionally skewed per node — heterogeneous
//! clusters). The distributed 2D-DFT follows the classic 1D (slab)
//! decomposition (Dmitruk et al., the paper's ref [11]): rows are
//! partitioned across nodes (hierarchically: HPOPTA across nodes using
//! node-aggregate speed functions, then the single-node PFFT machinery
//! within each node), and the transpose becomes an all-to-all exchange
//! priced by a latency/bandwidth (α-β) model.

use crate::coordinator::fpm::Curve;
use crate::coordinator::partition::{balanced, hpopta, PartitionError};
use crate::simulator::fpm::{SimTestbed, GRID_STEP};
use crate::simulator::vexec::{app_flops, transpose_time};
use crate::simulator::Package;

/// α-β communication model for the all-to-all transpose.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// per-message latency (s)
    pub alpha: f64,
    /// link bandwidth (B/s)
    pub beta: f64,
}

impl Default for NetModel {
    fn default() -> Self {
        // 10 GbE-class interconnect
        NetModel { alpha: 20e-6, beta: 1.25e9 }
    }
}

/// A virtual cluster: k nodes running `package`, node i's compute skewed
/// by `skew[i]` (1.0 = identical; ≠1.0 models a heterogeneous cluster).
#[derive(Clone, Debug)]
pub struct VirtualCluster {
    pub testbed: SimTestbed,
    pub skew: Vec<f64>,
    pub net: NetModel,
}

impl VirtualCluster {
    pub fn homogeneous(package: Package, k: usize) -> Self {
        VirtualCluster {
            testbed: SimTestbed::paper_best(package),
            skew: vec![1.0; k],
            net: NetModel::default(),
        }
    }

    /// Heterogeneous: node i runs at 1.0 / (1 + i·spread) of node 0.
    pub fn heterogeneous(package: Package, k: usize, spread: f64) -> Self {
        VirtualCluster {
            testbed: SimTestbed::paper_best(package),
            skew: (0..k).map(|i| 1.0 / (1.0 + i as f64 * spread)).collect(),
            net: NetModel::default(),
        }
    }

    pub fn nodes(&self) -> usize {
        self.skew.len()
    }

    /// Node-aggregate speed curve at plane y = n: the node's p groups
    /// sum (they run concurrently within the node), scaled by skew.
    pub fn node_curve(&self, node: usize, n: usize) -> Curve {
        let sections = self.testbed.plane_sections(n);
        let base = &sections[0];
        let mut speeds = vec![0.0f64; base.len()];
        for sec in &sections {
            for (k, &s) in sec.speeds.iter().enumerate() {
                speeds[k] += s;
            }
        }
        for s in &mut speeds {
            *s *= self.skew[node];
        }
        Curve::new(base.xs.clone(), speeds)
    }

    /// All-to-all exchange time for redistributing an n×n complex-double
    /// matrix across k nodes: each node sends (k−1)/k of its slab.
    pub fn alltoall_time(&self, n: usize) -> f64 {
        let k = self.nodes() as f64;
        if k <= 1.0 {
            return 0.0;
        }
        let bytes_total = 16.0 * (n as f64) * (n as f64);
        let per_node = bytes_total / k * (k - 1.0) / k;
        // k−1 messages per node, pipelined across the fabric
        (k - 1.0) * self.net.alpha + per_node / self.net.beta
    }

    /// Distributed 2D-DFT time with model-based (HPOPTA) node-level
    /// partitioning. Returns (total seconds, node distribution).
    pub fn dft2d_time_fpm(&self, n: usize) -> Result<(f64, Vec<usize>), PartitionError> {
        let curves: Vec<Curve> = (0..self.nodes()).map(|i| self.node_curve(i, n)).collect();
        let n_grid = n - n % GRID_STEP;
        let part = hpopta(&curves, n_grid)?;
        Ok((self.time_for_distribution(&part.d, n, &curves), part.d))
    }

    /// Distributed 2D-DFT time with the balanced (homogeneous) split.
    pub fn dft2d_time_balanced(&self, n: usize) -> f64 {
        let curves: Vec<Curve> = (0..self.nodes()).map(|i| self.node_curve(i, n)).collect();
        let n_grid = n - n % GRID_STEP;
        let d = balanced(self.nodes(), n_grid).d;
        self.time_for_distribution(&d, n, &curves)
    }

    fn time_for_distribution(&self, d: &[usize], n: usize, curves: &[Curve]) -> f64 {
        // two row phases (slowest node) + two all-to-all transposes +
        // local blocked transposes
        let phase = d
            .iter()
            .zip(curves)
            .filter(|(&di, _)| di > 0)
            .map(|(&di, c)| {
                let flops = 2.5 * di as f64 * n as f64 * (n as f64).log2();
                flops / (c.speed_nearest(di) * 1e6)
            })
            .fold(0.0f64, f64::max);
        2.0 * phase + 2.0 * self.alltoall_time(n) + 2.0 * transpose_time(n) / self.nodes() as f64
    }

    /// Single-node reference time (the scaling baseline).
    pub fn single_node_time(&self, n: usize) -> f64 {
        app_flops(n) / (self.testbed.model.speed(n) * 1e6) + 2.0 * transpose_time(n)
    }
}

/// Strong-scaling record for the cluster figure.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub nodes: usize,
    pub t_fpm: f64,
    pub t_balanced: f64,
    pub speedup_vs_single: f64,
}

/// Sweep node counts for one problem size.
pub fn strong_scaling(
    package: Package,
    n: usize,
    node_counts: &[usize],
    spread: f64,
) -> Vec<ScalingPoint> {
    node_counts
        .iter()
        .map(|&k| {
            let cluster = if spread == 0.0 {
                VirtualCluster::homogeneous(package, k)
            } else {
                VirtualCluster::heterogeneous(package, k, spread)
            };
            let single = cluster.single_node_time(n);
            let (t_fpm, _) = cluster.dft2d_time_fpm(n).expect("feasible");
            let t_balanced = cluster.dft2d_time_balanced(n);
            ScalingPoint { nodes: k, t_fpm, t_balanced, speedup_vs_single: single / t_fpm }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alltoall_zero_for_single_node() {
        let c = VirtualCluster::homogeneous(Package::Mkl, 1);
        assert_eq!(c.alltoall_time(10_000), 0.0);
    }

    #[test]
    fn alltoall_grows_with_size_and_nodes() {
        let c2 = VirtualCluster::homogeneous(Package::Mkl, 2);
        let c8 = VirtualCluster::homogeneous(Package::Mkl, 8);
        assert!(c2.alltoall_time(20_000) > c2.alltoall_time(10_000));
        // more nodes: less data per node but more latency terms
        assert!(c8.alltoall_time(10_000) < c2.alltoall_time(10_000) * 4.0);
    }

    #[test]
    fn homogeneous_scaling_improves_then_saturates() {
        let pts = strong_scaling(Package::Fftw3, 24_704, &[1, 2, 4, 8], 0.0);
        assert!(pts[1].speedup_vs_single > pts[0].speedup_vs_single);
        // compute share shrinks with k; comm does not — speedup is sublinear
        let eff8 = pts[3].speedup_vs_single / 8.0;
        assert!(eff8 < 1.0, "efficiency {eff8}");
    }

    #[test]
    fn heterogeneous_fpm_beats_balanced() {
        // with 40% per-node skew, balanced splits stall on the slow node
        let cluster = VirtualCluster::heterogeneous(Package::Mkl, 4, 0.4);
        let (t_fpm, d) = cluster.dft2d_time_fpm(24_704).unwrap();
        let t_bal = cluster.dft2d_time_balanced(24_704);
        assert!(t_fpm < t_bal, "fpm {t_fpm} balanced {t_bal}");
        // faster nodes get more rows
        assert!(d[0] > d[3], "{d:?}");
    }

    #[test]
    fn node_curve_skew_applied() {
        let cluster = VirtualCluster::heterogeneous(Package::Mkl, 2, 1.0);
        let fast = cluster.node_curve(0, 4_096);
        let slow = cluster.node_curve(1, 4_096);
        for (a, b) in fast.speeds.iter().zip(&slow.speeds) {
            assert!((a / b - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic() {
        let a = strong_scaling(Package::Fftw3, 12_800, &[2, 4], 0.0);
        let b = strong_scaling(Package::Fftw3, 12_800, &[2, 4], 0.0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.t_fpm.to_bits(), y.t_fpm.to_bits());
        }
    }
}
