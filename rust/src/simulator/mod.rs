//! Virtual testbed — calibrated performance models of the paper's setup.
//!
//! The paper's experiments need a 2×18-core Haswell server running
//! FFTW-2.1.5, FFTW-3.3.7 and Intel MKL FFT; none are available here
//! (repro band 0/5), so this module substitutes a *performance simulator*
//! that reproduces the published statistics of those packages:
//!
//! * [`packages`] — per-package speed profiles `s(N)` (envelope × noise)
//!   calibrated to the paper's peaks, averages and variation widths
//!   (Figures 1-6), with the drop *structure* (x-keyed vs y-keyed) that
//!   makes PFFT-FPM vs PFFT-FPM-PAD behave as published (see DESIGN.md
//!   §6 for the mechanism),
//! * [`fpm`] — simulated FPM surfaces `s_i(x, y)` for p groups of t
//!   threads (Figures 9-14),
//! * [`vexec`] — the virtual-time executor that runs the paper's whole
//!   evaluation campaign (Figures 15-26 + §V-F summary) in model time.
//!
//! Everything is deterministic (splitmix64 hash noise keyed by
//! `(package, coordinate)`), so every figure regenerates bit-identically.

pub mod cluster;
pub mod fpm;
pub mod packages;
pub mod vexec;

/// The three FFT packages the paper studies. `Ord` so the typed engine
/// ids built on top ([`crate::coordinator::engine::EngineId`]) can key
/// ordered maps (wisdom records, portfolio surfaces).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Package {
    Fftw2,
    Fftw3,
    Mkl,
}

impl Package {
    pub fn name(&self) -> &'static str {
        match self {
            Package::Fftw2 => "FFTW-2.1.5",
            Package::Fftw3 => "FFTW-3.3.7",
            Package::Mkl => "Intel MKL FFT",
        }
    }

    /// Short lowercase tag — the suffix of the `sim-<pkg>` engine ids
    /// ([`crate::coordinator::engine::EngineId::Sim`]). Must stay stable:
    /// it is the persisted wisdom / wire spelling of a virtual engine.
    pub fn short_name(&self) -> &'static str {
        match self {
            Package::Fftw2 => "fftw2",
            Package::Fftw3 => "fftw3",
            Package::Mkl => "mkl",
        }
    }

    pub fn parse(s: &str) -> Option<Package> {
        match s.to_ascii_lowercase().as_str() {
            "fftw2" | "fftw-2.1.5" => Some(Package::Fftw2),
            "fftw3" | "fftw-3.3.7" => Some(Package::Fftw3),
            "mkl" | "intel-mkl" | "intel mkl fft" => Some(Package::Mkl),
            _ => None,
        }
    }

    /// Hash tag for noise keying.
    pub(crate) fn tag(&self) -> u64 {
        match self {
            Package::Fftw2 => 0x2157,
            Package::Fftw3 => 0x3377,
            Package::Mkl => 0x4D4B,
        }
    }

    /// The paper's experimentally-best (p, t) for this package (§IV-A).
    pub fn best_groups(&self) -> crate::coordinator::group::GroupConfig {
        use crate::coordinator::group::GroupConfig;
        match self {
            // FFTW-2.1.5 is never optimized in the paper (poor threaded
            // row-FFT support) — give it the FFTW split for completeness.
            Package::Fftw2 => GroupConfig::new(4, 9),
            Package::Fftw3 => GroupConfig::new(4, 9),
            Package::Mkl => GroupConfig::new(2, 18),
        }
    }
}

/// The paper's problem-size grid: N ∈ {128, 192, ..., 64000} step 64
/// ("around 1000 problem sizes").
pub fn paper_sizes() -> Vec<usize> {
    (0..).map(|k| 128 + 64 * k).take_while(|&n| n <= 64000).collect()
}

/// The evaluation campaign sizes ("out of 700"): the first 700 grid
/// points, N ≤ 44864.
pub fn campaign_sizes() -> Vec<usize> {
    paper_sizes().into_iter().take(700).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_shape() {
        let sizes = paper_sizes();
        assert_eq!(sizes[0], 128);
        assert_eq!(sizes[1], 192);
        assert_eq!(*sizes.last().unwrap(), 64_000);
        assert!((995..=1000).contains(&sizes.len()), "{}", sizes.len());
    }

    #[test]
    fn campaign_is_700() {
        let sizes = campaign_sizes();
        assert_eq!(sizes.len(), 700);
        assert_eq!(*sizes.last().unwrap(), 128 + 64 * 699);
    }

    #[test]
    fn package_parse() {
        assert_eq!(Package::parse("mkl"), Some(Package::Mkl));
        assert_eq!(Package::parse("FFTW3"), Some(Package::Fftw3));
        assert_eq!(Package::parse("fftw-2.1.5"), Some(Package::Fftw2));
        assert_eq!(Package::parse("cufft"), None);
        // short names parse back (the persisted engine-id spelling)
        for p in [Package::Fftw2, Package::Fftw3, Package::Mkl] {
            assert_eq!(Package::parse(p.short_name()), Some(p));
        }
    }

    #[test]
    fn best_groups_match_paper() {
        assert_eq!(Package::Mkl.best_groups().p, 2);
        assert_eq!(Package::Mkl.best_groups().t, 18);
        assert_eq!(Package::Fftw3.best_groups().p, 4);
        assert_eq!(Package::Fftw3.best_groups().t, 9);
    }
}
