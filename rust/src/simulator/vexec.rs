//! Virtual-time executor: runs the paper's evaluation campaign
//! (Figures 15-26 + §V-F) against the calibrated package models.
//!
//! Time model (consistent basis everywhere):
//!
//! * whole-app flops of an N×N 2D-DFT: `5·N²·log2 N` (two row phases of
//!   the paper's `2.5·x·y·log2 y` speed formula),
//! * basic package run: `t = flops / s_pkg(N)` — the package curve is the
//!   measured whole-app speed, exactly what Figures 1-6 plot,
//! * PFFT variants: two row phases (`max_i` over abstract processors from
//!   the simulated FPM surfaces) plus two blocked transposes at a fixed
//!   byte rate.

use crate::coordinator::group::GroupConfig;
use crate::coordinator::pad::{determine_pad_length, PadCost, PadDecision};
use crate::coordinator::partition::{
    average_curve, curves_identical, hpopta, popta, Partition, PartitionError,
};
use crate::simulator::fpm::{SimTestbed, GRID_STEP};
use crate::simulator::Package;

/// Whole-application complex-flop count of an N×N 2D-DFT.
pub fn app_flops(n: usize) -> f64 {
    5.0 * (n as f64) * (n as f64) * (n as f64).log2()
}

/// Per-phase flops of x rows of length y.
fn phase_flops(x: usize, y: usize) -> f64 {
    2.5 * x as f64 * y as f64 * (y as f64).log2()
}

/// Transpose model: bytes moved / sustained rate. 16 B/element complex
/// double, read+write, at 25 GB/s effective (Haswell-class blocked
/// in-place transpose). Charged symmetrically to the basic run and to
/// the PFFT variants (all use the same Appendix-A transpose).
pub fn transpose_time(n: usize) -> f64 {
    2.0 * 16.0 * (n as f64) * (n as f64) / 25.0e9
}

/// ε for the Step-1b identity test in the virtual campaign (paper: 0.05).
pub const EPS_IDENTICAL: f64 = 0.05;

/// Pad search window above N (bytes-bounded as §V-B; 4096 on the
/// 128-grid = 32 candidates).
pub const PAD_WINDOW: usize = 4096;

/// One campaign point — everything Figures 15-26 need for size N.
#[derive(Clone, Debug)]
pub struct CampaignPoint {
    pub n: usize,
    /// basic package execution time (one 36-thread group)
    pub t_basic: f64,
    pub t_fpm: f64,
    pub t_pad: f64,
    /// FPM row distribution and padded lengths
    pub d: Vec<usize>,
    pub pads: Vec<usize>,
    pub used_hpopta: bool,
}

impl CampaignPoint {
    pub fn speedup_fpm(&self) -> f64 {
        self.t_basic / self.t_fpm
    }
    pub fn speedup_pad(&self) -> f64 {
        self.t_basic / self.t_pad
    }
    /// Whole-app speed (MFLOPs) of a variant given its time.
    pub fn mflops(&self, t: f64) -> f64 {
        app_flops(self.n) / t / 1e6
    }
}

/// Campaign results for one package.
#[derive(Clone, Debug)]
pub struct Campaign {
    pub package: Package,
    pub cfg: GroupConfig,
    pub points: Vec<CampaignPoint>,
}

impl Campaign {
    /// Run the virtual campaign over `sizes` with the package's
    /// paper-best (p, t).
    pub fn run(package: Package, sizes: &[usize]) -> Campaign {
        let tb = SimTestbed::paper_best(package);
        let points = sizes.iter().map(|&n| simulate_size(&tb, n)).collect();
        Campaign { package, cfg: tb.cfg, points }
    }

    pub fn summary(&self) -> CampaignSummary {
        CampaignSummary::from_points(&self.points)
    }
}

/// §V-F summary statistics.
#[derive(Clone, Debug, Default)]
pub struct CampaignSummary {
    pub count: usize,
    pub avg_speedup_fpm: f64,
    pub max_speedup_fpm: f64,
    pub avg_speedup_pad: f64,
    pub max_speedup_pad: f64,
    pub avg_mflops_basic: f64,
    pub avg_mflops_fpm: f64,
    pub avg_mflops_pad: f64,
}

impl CampaignSummary {
    pub fn from_points(points: &[CampaignPoint]) -> Self {
        if points.is_empty() {
            return Self::default();
        }
        let nf = points.len() as f64;
        let mut s = CampaignSummary { count: points.len(), ..Default::default() };
        for p in points {
            s.avg_speedup_fpm += p.speedup_fpm() / nf;
            s.avg_speedup_pad += p.speedup_pad() / nf;
            s.max_speedup_fpm = s.max_speedup_fpm.max(p.speedup_fpm());
            s.max_speedup_pad = s.max_speedup_pad.max(p.speedup_pad());
            s.avg_mflops_basic += p.mflops(p.t_basic) / nf;
            s.avg_mflops_fpm += p.mflops(p.t_fpm) / nf;
            s.avg_mflops_pad += p.mflops(p.t_pad) / nf;
        }
        s
    }

    /// Restrict to a size range (the paper's three ranges in §V-F).
    pub fn for_range(points: &[CampaignPoint], lo: usize, hi: usize) -> Self {
        let subset: Vec<CampaignPoint> =
            points.iter().filter(|p| p.n > lo && p.n <= hi).cloned().collect();
        Self::from_points(&subset)
    }
}

/// Simulate one problem size end-to-end: plan (Steps 1a-1d), pad
/// (Step 2), and price all three executions in virtual time.
pub fn simulate_size(tb: &SimTestbed, n: usize) -> CampaignPoint {
    // basic pays the same two transposes the PFFT variants do: the
    // package curve prices the row-FFT phases
    let t_basic = app_flops(n) / (tb.model.speed(n) * 1e6) + 2.0 * transpose_time(n);

    let (part, used_hpopta) = plan(tb, n);
    let d = part.d;

    // FPM phase time: slowest group, using each group's surface at y = n
    let phase_fpm = d
        .iter()
        .enumerate()
        .filter(|(_, &di)| di > 0)
        .map(|(i, &di)| phase_flops(di, n) / (tb.model.group_speed(di, n, i + 1, tb.cfg.p, tb.cfg.t) * 1e6))
        .fold(0.0f64, f64::max);
    // the workload-footprint drop is undodgeable — it scales every
    // variant's row phases identically (basic has it inside speed())
    let common_keep = 1.0 - tb.model.common_drop(n);
    let t_fpm = 2.0 * phase_fpm / common_keep + 2.0 * transpose_time(n);

    // PAD: per-group pad decision from the column section x = d_i
    let mut pads = Vec::with_capacity(d.len());
    let mut phase_pad = 0.0f64;
    for (i, &di) in d.iter().enumerate() {
        if di == 0 {
            pads.push(n);
            continue;
        }
        let col = tb.column_section(i + 1, di, n, PAD_WINDOW);
        let dec: PadDecision = determine_pad_length(&col, di, n, PadCost::PaperRatio);
        let v = dec.n_padded;
        let t = phase_flops(di, v)
            / (tb.model.group_speed(di, v, i + 1, tb.cfg.p, tb.cfg.t) * 1e6);
        phase_pad = phase_pad.max(t);
        pads.push(v);
    }
    let t_pad = 2.0 * phase_pad / common_keep + 2.0 * transpose_time(n);

    CampaignPoint { n, t_basic, t_fpm, t_pad, d, pads, used_hpopta }
}

/// One-stop virtual prediction for a package at size N — used by the
/// `service` layer's deterministic virtual-time path: the returned
/// point's `d`/`pads` seed a wisdom record and `t_fpm`/`t_pad` price the
/// request in virtual seconds (no real FFT executes).
pub fn predict_point(package: Package, n: usize) -> CampaignPoint {
    let tb = SimTestbed::paper_best(package);
    simulate_size(&tb, n)
}

/// Steps 1a-1d on the virtual testbed, with 64-remainder handling: the
/// FPM grid is 128-stepped (§V-B) while app sizes step 64; the remainder
/// rows go to the group whose marginal time grows least.
fn plan(tb: &SimTestbed, n: usize) -> (Partition, bool) {
    let n_grid = n - n % GRID_STEP;
    let curves = tb.plane_sections(n);
    let (part, hp) = if curves_identical(&curves, EPS_IDENTICAL) {
        let avg = average_curve(&curves);
        (popta(&avg, tb.cfg.p, n_grid), false)
    } else {
        (hpopta(&curves, n_grid), true)
    };
    // partitioning can only fail on degenerate grids (n below the grid
    // step); fall back to giving everything to group 1
    let mut part = match part {
        Ok(p) => p,
        Err(PartitionError::Unreachable { .. }) | Err(_) => {
            let mut d = vec![0; tb.cfg.p];
            d[0] = n_grid;
            Partition {
                d,
                makespan: f64::INFINITY,
                algorithm: crate::coordinator::partition::Algorithm::Balanced,
            }
        }
    };
    let rem = n - n_grid;
    if rem > 0 {
        if curves.iter().all(|c| !c.is_empty()) {
            // marginal-cost choice on nearest grid speeds
            let best = (0..part.d.len())
                .min_by(|&a, &b| {
                    let ca = marginal(&curves[a], part.d[a], rem);
                    let cb = marginal(&curves[b], part.d[b], rem);
                    ca.partial_cmp(&cb).unwrap()
                })
                .unwrap();
            part.d[best] += rem;
        } else {
            // below the FPM grid step there are no sections to consult —
            // everything goes to group 1 (sub-grid sizes are not a
            // modeled regime, just keep them total-preserving)
            part.d[0] += rem;
        }
    }
    (part, hp)
}

fn marginal(curve: &crate::coordinator::fpm::Curve, d: usize, rem: usize) -> f64 {
    let s = curve.speed_nearest((d + rem).max(GRID_STEP));
    (d + rem) as f64 / s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_sizes() -> Vec<usize> {
        // a representative sample across the three ranges (cheap in debug)
        vec![128, 192, 1024, 2816, 8000, 12_800, 16_384, 24_704, 33_024, 40_000]
    }

    #[test]
    fn times_positive_and_distribution_sums() {
        for pkg in [Package::Fftw3, Package::Mkl] {
            let c = Campaign::run(pkg, &small_sizes());
            for p in &c.points {
                assert!(p.t_basic > 0.0 && p.t_fpm > 0.0 && p.t_pad > 0.0);
                assert_eq!(p.d.iter().sum::<usize>(), p.n, "n={}", p.n);
                assert_eq!(p.d.len(), c.cfg.p);
                for (&di, &v) in p.d.iter().zip(&p.pads) {
                    assert!(v >= p.n, "pad below n");
                    let _ = di;
                }
            }
        }
    }

    #[test]
    fn pad_never_slower_than_fpm_in_model() {
        // pad picks argmin including "no pad", so modeled pad phase time
        // can exceed fpm only through the paper-ratio-vs-flops mismatch;
        // allow a small tolerance for that known bias.
        let c = Campaign::run(Package::Mkl, &small_sizes());
        for p in &c.points {
            assert!(
                p.t_pad <= p.t_fpm * 1.35,
                "n={}: pad {} vs fpm {}",
                p.n,
                p.t_pad,
                p.t_fpm
            );
        }
    }

    #[test]
    fn mid_range_speedups_dominate() {
        // §V-F: speedups concentrated in 10000 < N <= 33000
        let sizes: Vec<usize> = (0..40).map(|k| 10_048 + 576 * k).collect();
        let lo_sizes: Vec<usize> = (0..20).map(|k| 1_024 + 448 * k).collect();
        let mid = Campaign::run(Package::Fftw3, &sizes).summary();
        let low = Campaign::run(Package::Fftw3, &lo_sizes).summary();
        assert!(
            mid.avg_speedup_fpm > low.avg_speedup_fpm,
            "mid {} low {}",
            mid.avg_speedup_fpm,
            low.avg_speedup_fpm
        );
    }

    #[test]
    fn determinism() {
        let a = Campaign::run(Package::Mkl, &[24_704]);
        let b = Campaign::run(Package::Mkl, &[24_704]);
        assert_eq!(a.points[0].d, b.points[0].d);
        assert_eq!(a.points[0].t_pad, b.points[0].t_pad);
    }

    #[test]
    fn predict_point_matches_campaign() {
        let p = predict_point(Package::Mkl, 24_704);
        let c = Campaign::run(Package::Mkl, &[24_704]);
        assert_eq!(p.d, c.points[0].d);
        assert_eq!(p.t_fpm, c.points[0].t_fpm);
    }

    #[test]
    fn summary_ranges() {
        let c = Campaign::run(Package::Mkl, &small_sizes());
        let all = c.summary();
        let mid = CampaignSummary::for_range(&c.points, 10_000, 33_000);
        assert!(all.count == small_sizes().len());
        assert!(mid.count < all.count);
        assert!(all.max_speedup_fpm >= all.avg_speedup_fpm);
    }
}

#[cfg(test)]
mod campaign_diag {
    use super::*;

    #[test]
    #[ignore]
    fn max_point_diag() {
        for pkg in [Package::Fftw3, Package::Mkl] {
            let tb = SimTestbed::paper_best(pkg);
            let c = Campaign::run(pkg, &crate::simulator::campaign_sizes());
            let pt = c.points.iter().max_by(|a, b| a.speedup_fpm().partial_cmp(&b.speedup_fpm()).unwrap()).unwrap();
            let n = pt.n;
            println!("{} max FPM at n={n}: sp {:.2} d={:?} hp={}", pkg.name(), pt.speedup_fpm(), pt.d, pt.used_hpopta);
            println!("  basic speed {:.0} env {:.0} drop {:.3}", tb.model.speed(n), tb.model.envelope(n), tb.model.drop_at(n, n, 0));
            for (i, &di) in pt.d.iter().enumerate() {
                if di == 0 { continue; }
                println!("  g{} d={di} speed {:.0} drop {:.3}", i+1, tb.model.group_speed(di, n, i+1, tb.cfg.p, tb.cfg.t), tb.model.drop_at(di, n, i+1));
            }
        }
    }

    #[test]
    #[ignore]
    fn low_range_diag() {
        let tb = SimTestbed::paper_best(Package::Mkl);
        for n in [512usize, 1024, 2048, 5120] {
            let p = simulate_size(&tb, n);
            let basic_speed = tb.model.speed(n);
            let g1 = tb.model.group_speed(p.d[0].max(128), n, 1, 2, 18);
            let g2 = tb.model.group_speed(p.d[1].max(128), n, 2, 2, 18);
            println!(
                "n={n}: d={:?} basic {basic_speed:.0} g1 {g1:.0} g2 {g2:.0} tb {:.2e} tf {:.2e} ttr {:.2e} sp {:.2}",
                p.d, p.t_basic, p.t_fpm, transpose_time(n), p.speedup_fpm()
            );
        }
    }

    /// Diagnostic (run with `--ignored --nocapture` in release):
    /// full-campaign headline numbers vs the paper's abstract.
    #[test]
    #[ignore]
    fn campaign_report() {
        for pkg in [Package::Fftw3, Package::Mkl] {
            let c = Campaign::run(pkg, &crate::simulator::campaign_sizes());
            let s = c.summary();
            let mid = CampaignSummary::for_range(&c.points, 10_000, 33_000);
            let low = CampaignSummary::for_range(&c.points, 0, 10_000);
            let high = CampaignSummary::for_range(&c.points, 33_000, usize::MAX);
            println!(
                "{}: FPM avg {:.2}x max {:.2}x | PAD avg {:.2}x max {:.2}x",
                pkg.name(), s.avg_speedup_fpm, s.max_speedup_fpm,
                s.avg_speedup_pad, s.max_speedup_pad
            );
            println!(
                "  mid  FPM {:.2}/{:.2} PAD {:.2}/{:.2}   low FPM {:.2} high FPM {:.2}",
                mid.avg_speedup_fpm, mid.max_speedup_fpm,
                mid.avg_speedup_pad, mid.max_speedup_pad,
                low.avg_speedup_fpm, high.avg_speedup_fpm
            );
            println!(
                "  avg MFLOPs basic {:.0} fpm {:.0} pad {:.0}",
                s.avg_mflops_basic, s.avg_mflops_fpm, s.avg_mflops_pad
            );
        }
    }
}
