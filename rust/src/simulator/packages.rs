//! Calibrated package speed profiles (Figures 1-6, 13-14).
//!
//! Each package's basic (one group, 36 threads) 2D-DFT speed is
//!
//!   s_pkg(N) = envelope_pkg(N) · (1 − drop_pkg(N))
//!
//! * `envelope` — smooth asymmetric log-Gaussian through the package's
//!   published peak, auto-scaled so the grid average matches the
//!   published average *after* noise (two-pass calibration in
//!   [`PackageModel::new`]).
//! * `drop` — deterministic hash noise composed of (i) small per-size
//!   jitter, (ii) heavy drop events with per-package density/depth (the
//!   paper's "width of performance variations"), (iii) a smooth-size
//!   bonus (radix-friendly sizes run fast — the mechanism behind the real
//!   packages' spikes).
//!
//! Crucially, the drop noise is split into an **x-keyed** component
//! (batch/row-count sensitive — dominant in FFTW-3.3.7) and a **y-keyed**
//! component (row-length sensitive — dominant in MKL). PFFT-FPM dodges
//! x-keyed drops by repartitioning rows; only PFFT-FPM-PAD dodges y-keyed
//! drops by changing the row length. This is what makes the two methods'
//! published speedup profiles qualitatively different (MKL: FPM ≤ 2×,
//! PAD up to 5.9×; FFTW3: FPM already 6.8×). See DESIGN.md §6.

use crate::simulator::Package;
use crate::util::prng::{hash_key, unit_f64};

/// Hash-noise channel tags.
const TAG_JITTER: u64 = 1;
const TAG_DROP_EVENT: u64 = 2;
const TAG_DROP_DEPTH: u64 = 3;
const TAG_XDROP: u64 = 4;
const TAG_YDROP: u64 = 5;
const TAG_COMMON: u64 = 6;
const TAG_BASIC: u64 = 7;

/// Per-package calibration constants (paper-published statistics).
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// published peak speed (MFLOPs) and its location N
    pub peak_mflops: f64,
    pub peak_n: usize,
    /// published grid-average speed (MFLOPs)
    pub avg_mflops: f64,
    /// probability of a heavy drop at a given size
    pub drop_density: f64,
    /// heavy drop depth range (fraction of envelope lost)
    pub drop_depth: (f64, f64),
    /// small per-size jitter amplitude (fraction)
    pub jitter: f64,
    /// weight of the x-keyed (row-count) drop channel; 1 − w is y-keyed
    pub x_weight: f64,
    /// log-Gaussian envelope widths (left of peak, right of peak), in ln-N
    pub sigma: (f64, f64),
    /// floor fraction of peak the envelope decays to at large N
    pub tail_floor: f64,
    /// basic-only (whole-machine, 36-thread) penalty channel: the
    /// cross-socket/NUMA losses that per-socket abstract-processor groups
    /// dodge — the mechanism behind the paper's PFFT speedups
    pub basic_density: f64,
    pub basic_depth: (f64, f64),
}

impl Package {
    pub fn calibration(&self) -> Calibration {
        match self {
            // last updated 1999; narrow variations, strong mid-size hump
            Package::Fftw2 => Calibration {
                peak_mflops: 17841.0,
                peak_n: 2816,
                avg_mflops: 7033.0,
                drop_density: 0.10,
                drop_depth: (0.05, 0.25),
                jitter: 0.04,
                x_weight: 0.5,
                sigma: (1.1, 0.8),
                tail_floor: 0.30,
                basic_density: 0.25,
                basic_depth: (0.10, 0.30),
            },
            // wide variations, batch-sensitive planner
            Package::Fftw3 => Calibration {
                peak_mflops: 16989.0,
                peak_n: 8000,
                avg_mflops: 5065.0,
                drop_density: 0.72,
                drop_depth: (0.50, 0.92),
                jitter: 0.06,
                x_weight: 0.70,
                sigma: (1.3, 1.5),
                tail_floor: 0.66,
                basic_density: 0.85,
                basic_depth: (0.40, 0.70),
            },
            // huge peak, severe length-keyed variations ("fill the picture")
            Package::Mkl => Calibration {
                peak_mflops: 39424.0,
                peak_n: 1792,
                avg_mflops: 9572.0,
                drop_density: 0.55,
                drop_depth: (0.40, 0.85),
                jitter: 0.12,
                x_weight: 0.15,
                sigma: (1.0, 1.2),
                tail_floor: 0.45,
                basic_density: 0.85,
                basic_depth: (0.25, 0.50),
            },
        }
    }
}

/// A calibrated package model over the paper's size grid.
#[derive(Clone, Debug)]
pub struct PackageModel {
    pub package: Package,
    pub cal: Calibration,
    /// envelope scale factor fitted so that mean(speed) == avg_mflops
    scale: f64,
}

impl PackageModel {
    /// Build and calibrate on the paper grid: fixed-point iteration of
    /// the envelope scale so the noisy grid average hits the published
    /// average (the pinned peak spike contributes mass, hence iterate).
    pub fn new(package: Package) -> Self {
        let cal = package.calibration();
        let mut model = PackageModel { package, cal, scale: 1.0 };
        let sizes = crate::simulator::paper_sizes();
        for _ in 0..4 {
            let mean: f64 =
                sizes.iter().map(|&n| model.speed(n)).sum::<f64>() / sizes.len() as f64;
            model.scale *= cal.avg_mflops / mean;
        }
        model
    }

    /// Narrow log-Gaussian spike pinning the published peak value at the
    /// published peak location (the real packages' best-tuned kernel
    /// size); negligible two grid steps away.
    fn peak_spike(&self, n: usize) -> f64 {
        let cal = &self.cal;
        let du = (n as f64).ln() - (cal.peak_n as f64).ln();
        cal.peak_mflops * (-du * du / (2.0 * 0.05 * 0.05)).exp()
    }

    /// Smooth envelope (MFLOPs, pre-noise) at size N.
    pub fn envelope(&self, n: usize) -> f64 {
        let cal = &self.cal;
        let u = (n as f64).ln();
        let up = (cal.peak_n as f64).ln();
        let sig = if u < up { cal.sigma.0 } else { cal.sigma.1 };
        let g = (-((u - up) * (u - up)) / (2.0 * sig * sig)).exp();
        let shape = cal.tail_floor + (1.0 - cal.tail_floor) * g;
        self.scale * cal.peak_mflops * shape
    }

    /// Basic (one 36-thread group) application speed at size N — this is
    /// what Figures 1-6 plot. Composed of the x- and y-keyed channels at
    /// x = N rows, y = N length.
    /// Undodgeable drop tied to the whole-workload footprint (memory /
    /// NUMA pressure of the N×N matrix): only bites at N > 33000, applies
    /// to basic *and* optimized runs alike — this is why the paper's
    /// optimized curves keep "major variations" in the high range (§V-F).
    pub fn common_drop(&self, n: usize) -> f64 {
        if n <= 33_000 {
            return 0.0;
        }
        let tag = self.package.tag();
        let event = unit_f64(hash_key(&[tag, TAG_COMMON, n as u64]));
        if event < 0.50 {
            0.55 * unit_f64(hash_key(&[tag, TAG_COMMON, TAG_DROP_DEPTH, n as u64]))
        } else {
            0.0
        }
    }

    pub fn speed(&self, n: usize) -> f64 {
        let keep = (1.0 - self.drop_at(n, n, 0)) * (1.0 - self.common_drop(n));
        (self.envelope(n) * keep)
            .max(self.peak_spike(n))
            .min(self.cal.peak_mflops)
            .max(1.0)
    }

    /// The composite drop fraction for a workload of `x` rows of length
    /// `y` on group `g` (g = 0 is the whole-machine group; g ≥ 1 are
    /// abstract processors, which see independently-keyed x-channels —
    /// NUMA placement differs per group).
    pub fn drop_at(&self, x: usize, y: usize, g: usize) -> f64 {
        let cal = &self.cal;
        let tag = self.package.tag();

        // per-channel event densities are weighted so the overall event
        // rate stays ~drop_density (independent channels would compound)
        let x_drop = heavy_drop(
            hash_key(&[tag, TAG_XDROP, g as u64, x as u64]),
            hash_key(&[tag, TAG_DROP_EVENT, TAG_XDROP, g as u64, x as u64]),
            cal,
            range_scale(y),
            cal.x_weight,
        );
        let y_drop = heavy_drop(
            hash_key(&[tag, TAG_YDROP, y as u64]),
            hash_key(&[tag, TAG_DROP_EVENT, TAG_YDROP, y as u64]),
            cal,
            range_scale(y),
            1.0 - cal.x_weight,
        );
        // whole-machine penalty: only the basic one-group-of-36 run pays
        let basic = if g == 0 {
            let ev = unit_f64(hash_key(&[tag, TAG_BASIC, TAG_DROP_EVENT, y as u64]));
            if ev < cal.basic_density * range_scale(y).min(1.25) {
                let (lo, hi) = cal.basic_depth;
                let d = unit_f64(hash_key(&[tag, TAG_BASIC, TAG_DROP_DEPTH, y as u64]));
                (lo + (hi - lo) * d) * range_scale(y).clamp(0.25, 1.0)
            } else {
                0.0
            }
        } else {
            0.0
        };

        let jitter = cal.jitter
            * (unit_f64(hash_key(&[tag, TAG_JITTER, g as u64, x as u64, y as u64])) - 0.5);

        // multiplicative channel composition: keep = prod(1 - channel);
        // a deep y-drop and a deep basic penalty stack realistically
        // instead of clamping (which produced unbounded speedup ratios)
        let friendly = smoothness_bonus(y);
        let dodge = 1.0 - friendly;
        let keep = (1.0 - cal.x_weight * x_drop * dodge)
            * (1.0 - (1.0 - cal.x_weight) * y_drop * dodge)
            * (1.0 - basic * dodge)
            * (1.0 - jitter);
        (1.0 - keep).clamp(0.0, 0.95)
    }

    /// Speed (MFLOPs) of `x` row-FFTs of length `y` executed by abstract
    /// group `g` (1-based) out of `p` groups of `t` threads each — the
    /// simulated FPM surface value used by [`crate::simulator::fpm`].
    pub fn group_speed(&self, x: usize, y: usize, g: usize, p: usize, t: usize) -> f64 {
        debug_assert!(g >= 1 && g <= p);
        // thread share of the machine envelope at the *row length* y
        let share = t as f64 / 36.0;
        // batch efficiency: small batches underutilize a group's threads
        let eff = x as f64 / (x as f64 + 0.75 * t as f64);
        // per-group NUMA asymmetry (deterministic, ±6%)
        let asym = 1.0
            + 0.12
                * (unit_f64(hash_key(&[self.package.tag(), 0xA5, g as u64, p as u64])) - 0.5);
        let keep = 1.0 - self.drop_at(x, y, g);
        (self.envelope(y) * share * eff * asym * keep).max(1.0)
    }
}

/// Heavy-drop channel: event hash decides occurrence (density), depth
/// hash the magnitude.
fn heavy_drop(depth_h: u64, event_h: u64, cal: &Calibration, scale: f64, density_w: f64) -> f64 {
    if unit_f64(event_h) < cal.drop_density * density_w * scale.min(1.25) {
        let (lo, hi) = cal.drop_depth;
        (lo + (hi - lo) * unit_f64(depth_h)) * scale.clamp(0.25, 1.0)
    } else {
        0.0
    }
}

/// Range modulation of drop severity (paper §V-F): mild below 10000,
/// severe in (10000, 33000], severe-and-sticky above 33000.
fn range_scale(n: usize) -> f64 {
    if n <= 10_000 {
        0.35
    } else if n <= 33_000 {
        1.25
    } else {
        1.0
    }
}

/// How radix-friendly a length is: 1.0 for powers of two, decaying with
/// the largest prime factor (mirrors real FFT libraries' mixed-radix
/// kernels). Deterministic, not hashed.
pub fn smoothness_bonus(mut y: usize) -> f64 {
    if y == 0 {
        return 0.0;
    }
    for f in [2usize, 3, 5, 7] {
        while y % f == 0 {
            y /= f;
        }
    }
    match y {
        1 => 0.9,        // 7-smooth: near-perfect kernels
        _ if y <= 13 => 0.5,
        _ if y <= 127 => 0.2,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::paper_sizes;
    use crate::stats::summary;

    fn profile(pkg: Package) -> Vec<f64> {
        let m = PackageModel::new(pkg);
        paper_sizes().iter().map(|&n| m.speed(n)).collect()
    }

    #[test]
    fn averages_match_paper() {
        for (pkg, want) in [
            (Package::Fftw2, 7033.0),
            (Package::Fftw3, 5065.0),
            (Package::Mkl, 9572.0),
        ] {
            let avg = summary(&profile(pkg)).mean;
            assert!(
                (avg - want).abs() / want < 0.01,
                "{}: avg {avg:.0} vs published {want}",
                pkg.name()
            );
        }
    }

    #[test]
    fn peaks_are_in_band() {
        // peak value within 20% of published, location within a factor ~2
        for (pkg, want_peak, want_n) in [
            (Package::Fftw2, 17841.0, 2816usize),
            (Package::Fftw3, 16989.0, 8000),
            (Package::Mkl, 39424.0, 1792),
        ] {
            let m = PackageModel::new(pkg);
            let sizes = paper_sizes();
            let (n_at, peak) = sizes
                .iter()
                .map(|&n| (n, m.speed(n)))
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            assert!(
                (peak - want_peak).abs() / want_peak < 0.35,
                "{}: peak {peak:.0} vs {want_peak}",
                pkg.name()
            );
            let ratio = n_at as f64 / want_n as f64;
            assert!(
                (0.4..2.5).contains(&ratio),
                "{}: peak at N={n_at} vs published {want_n}",
                pkg.name()
            );
        }
    }

    #[test]
    fn mkl_variation_widest_fftw2_narrowest() {
        // The paper's "width of performance variations" (Eq. 1, visible
        // band of the profile): MKL's variations "almost fill the
        // picture", FFTW-2.1.5's are narrowest. Measured here as the mean
        // absolute speed swing between adjacent sizes (MFLOPs).
        let mut widths = Vec::new();
        for pkg in [Package::Fftw2, Package::Fftw3, Package::Mkl] {
            let p = profile(pkg);
            let w: f64 = p.windows(2).map(|w| (w[0] - w[1]).abs()).sum::<f64>()
                / (p.len() - 1) as f64;
            widths.push(w);
        }
        assert!(widths[0] < widths[1], "fftw2 {} < fftw3 {}", widths[0], widths[1]);
        assert!(widths[1] < widths[2], "fftw3 {} < mkl {}", widths[1], widths[2]);
    }

    #[test]
    fn win_counts_in_band() {
        // paper: FFTW2 beats FFTW3 on 529/1000; beats MKL on 162/1000;
        // FFTW3 beats MKL on 199/1000. Bands are generous — the *shape*
        // (who wins how often) is what must hold.
        let f2 = profile(Package::Fftw2);
        let f3 = profile(Package::Fftw3);
        let mk = profile(Package::Mkl);
        let wins = |a: &[f64], b: &[f64]| a.iter().zip(b).filter(|(x, y)| x > y).count();
        let n = f2.len() as f64;
        let w23 = wins(&f2, &f3) as f64 / n;
        let w2m = wins(&f2, &mk) as f64 / n;
        let w3m = wins(&f3, &mk) as f64 / n;
        assert!((0.40..=0.82).contains(&w23), "fftw2>fftw3 rate {w23}");
        assert!((0.08..=0.32).contains(&w2m), "fftw2>mkl rate {w2m}");
        assert!((0.08..=0.33).contains(&w3m), "fftw3>mkl rate {w3m}");
    }

    #[test]
    fn determinism() {
        let a = PackageModel::new(Package::Mkl);
        let b = PackageModel::new(Package::Mkl);
        for &n in &[128usize, 4096, 24704, 63936] {
            assert_eq!(a.speed(n), b.speed(n));
            assert_eq!(a.group_speed(128, n, 1, 2, 18), b.group_speed(128, n, 1, 2, 18));
        }
    }

    #[test]
    fn group_speed_scales_with_threads() {
        let m = PackageModel::new(Package::Mkl);
        // more threads per group → more speed at large batch
        let s18 = m.group_speed(8192, 16384, 1, 2, 18);
        let s9 = m.group_speed(8192, 16384, 1, 4, 9);
        assert!(s18 > s9, "18t {s18} vs 9t {s9}");
    }

    #[test]
    fn smoothness_bonus_ordering() {
        assert_eq!(smoothness_bonus(4096), 0.9);
        assert_eq!(smoothness_bonus(3840), 0.9); // 2^8·3·5
        assert!(smoothness_bonus(24704) < 0.9); // 2^7·193
        assert_eq!(smoothness_bonus(24704), 0.0);
    }

    /// Diagnostic (run with `--ignored --nocapture`): calibration report
    /// used while tuning the constants against the paper's statistics.
    #[test]
    #[ignore]
    fn calibration_report() {
        let f2 = profile(Package::Fftw2);
        let f3 = profile(Package::Fftw3);
        let mk = profile(Package::Mkl);
        let sizes = paper_sizes();
        for (name, p) in [("fftw2", &f2), ("fftw3", &f3), ("mkl", &mk)] {
            let s = summary(p);
            let peak_at = sizes[p.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0];
            println!("{name}: avg {:.0} peak {:.0} @ N={peak_at}", s.mean, s.max);
        }
        let wins = |a: &[f64], b: &[f64]| a.iter().zip(b).filter(|(x, y)| x > y).count();
        println!("fftw2>fftw3: {}/1000(paper 529)", wins(&f2, &f3));
        println!("fftw2>mkl:   {}/1000 (paper 162)", wins(&f2, &mk));
        println!("fftw3>mkl:   {}/1000 (paper 199)", wins(&f3, &mk));
        // envelopes and range-resolved wins
        let m2 = PackageModel::new(Package::Fftw2);
        let m3 = PackageModel::new(Package::Fftw3);
        let mm = PackageModel::new(Package::Mkl);
        for n in [512usize, 2048, 8000, 16000, 32000, 48000, 64000] {
            println!(
                "env @{n}: f2 {:.0} f3 {:.0} mkl {:.0}",
                m2.envelope(n),
                m3.envelope(n),
                mm.envelope(n)
            );
        }
        for (lo, hi) in [(0usize, 10_000usize), (10_000, 33_000), (33_000, 64_001)] {
            let idx: Vec<usize> = sizes
                .iter()
                .enumerate()
                .filter(|(_, &n)| n > lo && n <= hi)
                .map(|(i, _)| i)
                .collect();
            let w = |a: &[f64], b: &[f64]| {
                idx.iter().filter(|&&i| a[i] > b[i]).count() as f64 / idx.len() as f64
            };
            println!(
                "range ({lo},{hi}]: f2>f3 {:.2} f2>mkl {:.2} f3>mkl {:.2}",
                w(&f2, &f3),
                w(&f2, &mk),
                w(&f3, &mk)
            );
        }
    }

    #[test]
    fn speeds_always_positive() {
        for pkg in [Package::Fftw2, Package::Fftw3, Package::Mkl] {
            let m = PackageModel::new(pkg);
            for &n in paper_sizes().iter().step_by(37) {
                assert!(m.speed(n) > 0.0);
                assert!(m.group_speed(n / 2, n, 1, 2, 18) > 0.0);
            }
        }
    }
}
