//! The real-machine analogue figure: measured (not simulated) speedups
//! of PFFT-FPM over PFFT-LB for the native and PJRT engines on small N,
//! plus a native-vs-PJRT numeric cross-check. This is the end-to-end
//! proof that the three layers compose (also exercised by
//! `examples/e2e_pipeline.rs`).

use crate::coordinator::engine::{NativeEngine, RowFftEngine};
use crate::coordinator::group::GroupConfig;
use crate::coordinator::pfft::{pfft_fpm, pfft_lb, plan_partition_fpms};
use crate::dft::SignalMatrix;
use crate::figures::Ctx;
use crate::profiler::build_plane;
use crate::runtime::PjrtRowFftEngine;
use crate::util::table::{fnum, Table};

pub fn generate(ctx: &Ctx) -> Result<String, String> {
    let cfg = GroupConfig::new(2, 1);
    let sizes = [128usize, 256, 512];
    let mut t = Table::new(
        "real — measured on this host (not simulated)",
        &["engine", "N", "t PFFT-LB (s)", "t PFFT-FPM (s)", "speedup", "xcheck rel err"],
    );

    // native engine rows
    run_engine(&NativeEngine, "native", &sizes, cfg, &mut t, None)?;

    // PJRT engine rows (needs artifacts)
    let pjrt = PjrtRowFftEngine::load(&ctx.artifacts_dir)
        .map_err(|e| format!("PJRT engine unavailable: {e}"))?;
    run_engine(&pjrt, "pjrt", &sizes, cfg, &mut t, Some(&NativeEngine))?;

    t.write_csv(&ctx.out_dir.join("fig_real.csv")).map_err(|e| e.to_string())?;
    Ok(t.render())
}

fn run_engine(
    engine: &dyn RowFftEngine,
    label: &str,
    sizes: &[usize],
    cfg: GroupConfig,
    t: &mut Table,
    xcheck: Option<&dyn RowFftEngine>,
) -> Result<(), String> {
    for &n in sizes {
        // profile a small plane and plan
        let xs: Vec<usize> = (1..=4).map(|k| k * n / 4).collect();
        let fpms = build_plane(engine, cfg, xs, n, 10_000);
        let part = plan_partition_fpms(&fpms, n, 0.05).map_err(|e| e.to_string())?;

        let orig = SignalMatrix::random(n, n, n as u64);
        let mut m_lb = orig.clone();
        let rep_lb = pfft_lb(engine, &mut m_lb, cfg, 64).map_err(|e| e.to_string())?;
        let mut m_fpm = orig.clone();
        let rep_fpm =
            pfft_fpm(engine, &mut m_fpm, &part.d, cfg.t, 64).map_err(|e| e.to_string())?;

        // cross-check against the oracle engine when given
        let err = match xcheck {
            Some(oracle) => {
                let mut m_ref = orig.clone();
                pfft_lb(oracle, &mut m_ref, cfg, 64).map_err(|e| e.to_string())?;
                m_fpm.max_abs_diff(&m_ref) / m_ref.norm().max(1.0)
            }
            None => {
                // self-consistency: LB and FPM must agree
                m_fpm.max_abs_diff(&m_lb) / m_lb.norm().max(1.0)
            }
        };

        t.row(vec![
            label.to_string(),
            n.to_string(),
            fnum(rep_lb.elapsed_s, 4),
            fnum(rep_fpm.elapsed_s, 4),
            fnum(rep_lb.elapsed_s / rep_fpm.elapsed_s.max(1e-12), 2),
            format!("{err:.2e}"),
        ]);
    }
    Ok(())
}

/// A lighter native-only variant used by the integration tests (no
/// artifacts needed).
pub fn native_only(ctx: &Ctx) -> Result<String, String> {
    let cfg = GroupConfig::new(2, 1);
    let mut t = Table::new("real (native only)", &["engine", "N", "t LB", "t FPM", "speedup", "err"]);
    run_engine(&NativeEngine, "native", &[64, 128], cfg, &mut t, None)?;
    t.write_csv(&ctx.out_dir.join("fig_real_native.csv")).map_err(|e| e.to_string())?;
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn native_only_runs_and_is_consistent() {
        let ctx = Ctx::new(Path::new("/tmp/hclfft_real"), true);
        let s = native_only(&ctx).unwrap();
        assert!(s.contains("native"));
        // consistency column: LB vs FPM output identical transform
        for line in s.lines().skip(2) {
            if let Some(err_s) = line.split_whitespace().last() {
                if let Ok(err) = err_s.parse::<f64>() {
                    assert!(err < 1e-9, "{line}");
                }
            }
        }
    }

    #[test]
    fn profile_spec_reachable() {
        // guard: ProfileSpec stays exported for examples
        let _ = crate::profiler::ProfileSpec::new(vec![4], vec![64], GroupConfig::new(1, 1));
    }
}
