//! Figure/table regeneration harness — one generator per item in the
//! paper's evaluation (see DESIGN.md §5 for the index).
//!
//! `hclfft figures --fig <id>` (or `--all`) renders each figure's series
//! as an aligned console table and writes `results/fig<id>.csv`. IDs:
//! `t1`, `1`..`26`, `summary`, `pad-ablation`, the extension figures
//! (`ext-dynamic`, `ext-cluster`, `ext-energy`, `ext-3d`) and `real`.
//!
//! Quick mode (`--quick`) decimates the size grids so the whole set
//! regenerates in seconds (used by the integration tests); full mode
//! reproduces the paper's grids exactly.

pub mod extensions;
pub mod illus;
pub mod profiles;
pub mod real;
pub mod sections;
pub mod speedups;
pub mod summary;
pub mod table1;

use std::path::Path;

/// Generation context.
#[derive(Clone, Debug)]
pub struct Ctx {
    pub out_dir: std::path::PathBuf,
    /// decimate campaign grids (1 = paper-exact)
    pub decimate: usize,
    /// artifacts dir for the `real` figure (PJRT engine)
    pub artifacts_dir: std::path::PathBuf,
}

impl Ctx {
    pub fn new(out_dir: &Path, quick: bool) -> Ctx {
        Ctx {
            out_dir: out_dir.to_path_buf(),
            decimate: if quick { 16 } else { 1 },
            artifacts_dir: std::path::PathBuf::from("artifacts"),
        }
    }

    /// The campaign sizes honouring decimation.
    pub fn campaign_sizes(&self) -> Vec<usize> {
        crate::simulator::campaign_sizes()
            .into_iter()
            .step_by(self.decimate.max(1))
            .collect()
    }

    /// The full profile grid honouring decimation.
    pub fn paper_sizes(&self) -> Vec<usize> {
        crate::simulator::paper_sizes()
            .into_iter()
            .step_by(self.decimate.max(1))
            .collect()
    }
}

/// All figure ids in paper order.
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "t1", "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11", "12", "13", "14", "15",
        "16", "17", "18", "19", "20", "21", "22", "23", "24", "25", "26", "summary",
        "pad-ablation", "ext-dynamic", "ext-cluster", "ext-energy", "ext-3d", "real",
    ]
}

/// Generate one figure; returns the rendered text.
pub fn generate(id: &str, ctx: &Ctx) -> Result<String, String> {
    match id {
        "t1" => Ok(table1::generate(ctx)),
        "1" => profiles::profile_pair(ctx, "fig1", crate::simulator::Package::Fftw2, crate::simulator::Package::Fftw3),
        "2" => profiles::average_pair(ctx, "fig2", crate::simulator::Package::Fftw2, crate::simulator::Package::Fftw3),
        "3" => profiles::profile_pair(ctx, "fig3", crate::simulator::Package::Fftw2, crate::simulator::Package::Mkl),
        "4" => profiles::average_pair(ctx, "fig4", crate::simulator::Package::Fftw2, crate::simulator::Package::Mkl),
        "5" => profiles::profile_pair(ctx, "fig5", crate::simulator::Package::Fftw3, crate::simulator::Package::Mkl),
        "6" => profiles::average_pair(ctx, "fig6", crate::simulator::Package::Fftw3, crate::simulator::Package::Mkl),
        "7" => Ok(illus::pfft_lb_illustration()),
        "8" => Ok(illus::pfft_fpm_illustration()),
        "9" => sections::plane_sections(ctx),
        "10" => sections::hpopta_partition(ctx),
        "11" => sections::column_sections(ctx),
        "12" => sections::pad_lengths(ctx),
        "13" => sections::full_surface(ctx, "fig13", crate::simulator::Package::Fftw3),
        "14" => sections::full_surface(ctx, "fig14", crate::simulator::Package::Mkl),
        "15" => speedups::speedups(ctx, "fig15", crate::simulator::Package::Fftw3, speedups::Series::Both),
        "16" => speedups::speedups(ctx, "fig16", crate::simulator::Package::Fftw3, speedups::Series::PadImprovedOnly),
        "17" => speedups::times(ctx, "fig17", crate::simulator::Package::Fftw3, speedups::Series::Both),
        "18" => speedups::times(ctx, "fig18", crate::simulator::Package::Fftw3, speedups::Series::FpmOnly),
        "19" => speedups::times(ctx, "fig19", crate::simulator::Package::Fftw3, speedups::Series::PadOnly),
        "20" => speedups::speedups(ctx, "fig20", crate::simulator::Package::Mkl, speedups::Series::Both),
        "21" => speedups::speedups(ctx, "fig21", crate::simulator::Package::Mkl, speedups::Series::PadImprovedOnly),
        "22" => speedups::times(ctx, "fig22", crate::simulator::Package::Mkl, speedups::Series::Both),
        "23" => speedups::times(ctx, "fig23", crate::simulator::Package::Mkl, speedups::Series::FpmOnly),
        "24" => speedups::times(ctx, "fig24", crate::simulator::Package::Mkl, speedups::Series::PadOnly),
        "25" => speedups::vs_fftw2(ctx, "fig25", crate::simulator::Package::Fftw3),
        "26" => speedups::vs_fftw2(ctx, "fig26", crate::simulator::Package::Mkl),
        "summary" => summary::generate(ctx),
        "pad-ablation" => speedups::pad_ablation(ctx),
        "ext-dynamic" => extensions::dynamic_ablation(ctx),
        "ext-cluster" => extensions::cluster_scaling(ctx),
        "ext-energy" => extensions::energy_pareto(ctx),
        "ext-3d" => extensions::dft3d_demo(ctx),
        "real" => real::generate(ctx),
        other => Err(format!("unknown figure id `{other}` (try --all; ids: {:?})", all_ids())),
    }
}

/// Generate every figure; returns the concatenated report.
pub fn generate_all(ctx: &Ctx) -> Result<String, String> {
    let mut out = String::new();
    for id in all_ids() {
        match generate(id, ctx) {
            Ok(text) => {
                out.push_str(&text);
                out.push('\n');
            }
            // the `real` figure needs artifacts; degrade gracefully
            Err(e) if id == "real" => {
                out.push_str(&format!("[fig real skipped: {e}]\n"));
            }
            Err(e) => return Err(format!("fig {id}: {e}")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_errors() {
        let ctx = Ctx::new(Path::new("/tmp/hclfft_figs"), true);
        assert!(generate("99", &ctx).is_err());
    }

    #[test]
    fn all_ids_cover_paper() {
        let ids = all_ids();
        // 26 figures + table 1 + summary + 2 extras
        assert!(ids.contains(&"t1"));
        for i in 1..=26 {
            assert!(ids.contains(&format!("{i}").as_str()), "missing fig {i}");
        }
        assert!(ids.contains(&"summary"));
    }
}
