//! Table I — specification of the (simulated) experimental platform.

use crate::figures::Ctx;
use crate::util::table::Table;

pub fn generate(ctx: &Ctx) -> String {
    let mut t = Table::new(
        "Table I — simulated Intel Haswell server (paper's testbed)",
        &["Technical Specifications", "Intel Haswell Server"],
    );
    for (k, v) in [
        ("Processor", "Intel Xeon CPU E5-2699 v3 @ 2.30GHz (simulated)"),
        ("OS", "CentOS 7.1.1503 (simulated)"),
        ("Microarchitecture", "Haswell"),
        ("Memory", "256 GB"),
        ("Core(s) per socket", "18"),
        ("Socket(s)", "2"),
        ("NUMA node(s)", "2"),
        ("L1d cache", "32 KB"),
        ("L1i cache", "32 KB"),
        ("L2 cache", "256 KB"),
        ("L3 cache", "46080 KB"),
        ("NUMA node0 CPU(s)", "0-17,36-53"),
        ("NUMA node1 CPU(s)", "18-35,54-71"),
    ] {
        t.row(vec![k.to_string(), v.to_string()]);
    }
    let _ = t.write_csv(&ctx.out_dir.join("table1.csv"));

    // also report the actual host this reproduction ran on
    let mut host = Table::new("Actual reproduction host", &["key", "value"]);
    host.row(vec!["cores".into(), std::thread::available_parallelism().map(|c| c.to_string()).unwrap_or_else(|_| "?".into())]);
    host.row(vec!["os".into(), std::env::consts::OS.to_string()]);
    host.row(vec!["arch".into(), std::env::consts::ARCH.to_string()]);
    host.row(vec!["engines".into(), "native rust FFT, PJRT CPU (AOT JAX/Pallas), virtual testbed".into()]);
    format!("{}\n{}", t.render(), host.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_both_tables() {
        let ctx = Ctx::new(std::path::Path::new("/tmp/hclfft_t1"), true);
        let s = generate(&ctx);
        assert!(s.contains("Haswell"));
        assert!(s.contains("NUMA node0"));
        assert!(s.contains("reproduction host"));
        assert!(std::path::Path::new("/tmp/hclfft_t1/table1.csv").exists());
    }
}
