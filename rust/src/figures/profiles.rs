//! Figures 1-6 — package performance profiles and average speeds.

use crate::figures::Ctx;
use crate::simulator::packages::PackageModel;
use crate::simulator::Package;
use crate::stats::summary;
use crate::util::table::{fnum, Table};

/// Figures 1/3/5: two packages' speed profiles over the paper grid.
pub fn profile_pair(ctx: &Ctx, name: &str, a: Package, b: Package) -> Result<String, String> {
    let ma = PackageModel::new(a);
    let mb = PackageModel::new(b);
    let sizes = ctx.paper_sizes();
    let mut t = Table::new(
        &format!("{name} — performance profiles: {} vs {}", a.name(), b.name()),
        &["N", &format!("{} MFLOPs", a.name()), &format!("{} MFLOPs", b.name())],
    );
    for &n in &sizes {
        t.row(vec![n.to_string(), fnum(ma.speed(n), 1), fnum(mb.speed(n), 1)]);
    }
    t.write_csv(&ctx.out_dir.join(format!("{name}.csv"))).map_err(|e| e.to_string())?;

    // console: print stats + a decimated view, not 1000 rows
    let sa: Vec<f64> = sizes.iter().map(|&n| ma.speed(n)).collect();
    let sb: Vec<f64> = sizes.iter().map(|&n| mb.speed(n)).collect();
    let (ta, tb) = (summary(&sa), summary(&sb));
    let wins = sa.iter().zip(&sb).filter(|(x, y)| x > y).count();
    let mut head = format!(
        "== {name}: {} vs {} ==\n  {}: avg {:.0} peak {:.0} MFLOPs\n  {}: avg {:.0} peak {:.0} MFLOPs\n  {} wins {wins}/{} sizes\n",
        a.name(), b.name(), a.name(), ta.mean, ta.max, b.name(), tb.mean, tb.max, a.name(), sizes.len(),
    );
    head.push_str(&decimated_view(&t, 12));
    Ok(head)
}

/// Figures 2/4/6: cumulative average speeds (the paper's "average
/// speeds" companion plots).
pub fn average_pair(ctx: &Ctx, name: &str, a: Package, b: Package) -> Result<String, String> {
    let ma = PackageModel::new(a);
    let mb = PackageModel::new(b);
    let sizes = ctx.paper_sizes();
    let mut t = Table::new(
        &format!("{name} — cumulative average speeds: {} vs {}", a.name(), b.name()),
        &["N", &format!("avg {}", a.name()), &format!("avg {}", b.name())],
    );
    let (mut sum_a, mut sum_b) = (0.0f64, 0.0f64);
    for (i, &n) in sizes.iter().enumerate() {
        sum_a += ma.speed(n);
        sum_b += mb.speed(n);
        let k = (i + 1) as f64;
        t.row(vec![n.to_string(), fnum(sum_a / k, 1), fnum(sum_b / k, 1)]);
    }
    t.write_csv(&ctx.out_dir.join(format!("{name}.csv"))).map_err(|e| e.to_string())?;
    let last = t.rows.last().cloned().unwrap_or_default();
    Ok(format!(
        "== {name}: cumulative averages ==\n  final: {} {} vs {} {} MFLOPs\n{}",
        a.name(),
        last.get(1).cloned().unwrap_or_default(),
        b.name(),
        last.get(2).cloned().unwrap_or_default(),
        decimated_view(&t, 10)
    ))
}

/// Render every k-th row of a table (console-sized view of a big series).
pub fn decimated_view(t: &Table, rows: usize) -> String {
    let step = (t.rows.len() / rows.max(1)).max(1);
    let mut small = Table::new(&t.title, &t.header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for r in t.rows.iter().step_by(step) {
        small.row(r.clone());
    }
    small.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn profile_pair_writes_csv_and_stats() {
        let ctx = Ctx::new(Path::new("/tmp/hclfft_profiles"), true);
        let s = profile_pair(&ctx, "figtest1", Package::Fftw2, Package::Fftw3).unwrap();
        assert!(s.contains("avg"));
        assert!(s.contains("wins"));
        let csv = std::fs::read_to_string("/tmp/hclfft_profiles/figtest1.csv").unwrap();
        assert!(csv.lines().count() > 10);
        assert!(csv.starts_with("N,"));
    }

    #[test]
    fn average_pair_is_cumulative() {
        let ctx = Ctx::new(Path::new("/tmp/hclfft_profiles"), true);
        let s = average_pair(&ctx, "figtest2", Package::Fftw3, Package::Mkl).unwrap();
        assert!(s.contains("final"));
        let csv = std::fs::read_to_string("/tmp/hclfft_profiles/figtest2.csv").unwrap();
        // cumulative average of MKL must end near its grid average on the
        // decimated grid — just sanity-check parse + monotone N column
        let mut last_n = 0usize;
        for line in csv.lines().skip(1) {
            let n: usize = line.split(',').next().unwrap().parse().unwrap();
            assert!(n > last_n);
            last_n = n;
        }
    }
}
