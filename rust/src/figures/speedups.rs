//! Figures 15-26 — speedups and execution times of PFFT-FPM /
//! PFFT-FPM-PAD over the basic packages, plus the optimized-vs-FFTW-2.1.5
//! comparisons, from the virtual campaign.

use crate::coordinator::pad::PadCost;
use crate::figures::Ctx;
use crate::simulator::packages::PackageModel;
use crate::simulator::vexec::{app_flops, transpose_time, Campaign, CampaignSummary};
use crate::simulator::Package;
use crate::util::table::{fnum, Table};

/// Which series a figure shows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Series {
    Both,
    FpmOnly,
    PadOnly,
    /// PAD, restricted to sizes where it improved (Figures 16/21)
    PadImprovedOnly,
}

/// Figures 15/16/20/21: speedup series.
pub fn speedups(ctx: &Ctx, name: &str, pkg: Package, series: Series) -> Result<String, String> {
    let c = Campaign::run(pkg, &ctx.campaign_sizes());
    let mut header = vec!["N".to_string()];
    match series {
        Series::Both => {
            header.push("speedup PFFT-FPM".into());
            header.push("speedup PFFT-FPM-PAD".into());
        }
        Series::FpmOnly => header.push("speedup PFFT-FPM".into()),
        Series::PadOnly | Series::PadImprovedOnly => header.push("speedup PFFT-FPM-PAD".into()),
    }
    let mut t = Table::new(
        &format!("{name} — speedup vs basic {} (36 threads)", pkg.name()),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for p in &c.points {
        match series {
            Series::Both => t.row(vec![
                p.n.to_string(),
                fnum(p.speedup_fpm(), 3),
                fnum(p.speedup_pad(), 3),
            ]),
            Series::FpmOnly => t.row(vec![p.n.to_string(), fnum(p.speedup_fpm(), 3)]),
            Series::PadOnly => t.row(vec![p.n.to_string(), fnum(p.speedup_pad(), 3)]),
            Series::PadImprovedOnly => {
                if p.speedup_pad() > 1.0 {
                    t.row(vec![p.n.to_string(), fnum(p.speedup_pad(), 3)]);
                }
            }
        }
    }
    t.write_csv(&ctx.out_dir.join(format!("{name}.csv"))).map_err(|e| e.to_string())?;
    let s = c.summary();
    Ok(format!(
        "== {name}: speedups over basic {} ==\n  FPM avg {:.2}x max {:.2}x | PAD avg {:.2}x max {:.2}x ({} sizes)\n{}",
        pkg.name(),
        s.avg_speedup_fpm,
        s.max_speedup_fpm,
        s.avg_speedup_pad,
        s.max_speedup_pad,
        s.count,
        crate::figures::profiles::decimated_view(&t, 12)
    ))
}

/// Figures 17-19/22-24: execution-time series.
pub fn times(ctx: &Ctx, name: &str, pkg: Package, series: Series) -> Result<String, String> {
    let c = Campaign::run(pkg, &ctx.campaign_sizes());
    let mut header = vec!["N".to_string(), format!("basic {} (s)", pkg.name())];
    match series {
        Series::Both => {
            header.push("PFFT-FPM (s)".into());
            header.push("PFFT-FPM-PAD (s)".into());
        }
        Series::FpmOnly => header.push("PFFT-FPM (s)".into()),
        Series::PadOnly | Series::PadImprovedOnly => header.push("PFFT-FPM-PAD (s)".into()),
    }
    let mut t = Table::new(
        &format!("{name} — execution times vs basic {}", pkg.name()),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for p in &c.points {
        let mut row = vec![p.n.to_string(), fnum(p.t_basic, 4)];
        match series {
            Series::Both => {
                row.push(fnum(p.t_fpm, 4));
                row.push(fnum(p.t_pad, 4));
            }
            Series::FpmOnly => row.push(fnum(p.t_fpm, 4)),
            Series::PadOnly | Series::PadImprovedOnly => row.push(fnum(p.t_pad, 4)),
        }
        t.row(row);
    }
    t.write_csv(&ctx.out_dir.join(format!("{name}.csv"))).map_err(|e| e.to_string())?;
    Ok(format!(
        "== {name}: execution times ==\n{}",
        crate::figures::profiles::decimated_view(&t, 12)
    ))
}

/// Figures 25/26: optimized package (PFFT-FPM-PAD) vs unoptimized
/// FFTW-2.1.5.
pub fn vs_fftw2(ctx: &Ctx, name: &str, pkg: Package) -> Result<String, String> {
    let c = Campaign::run(pkg, &ctx.campaign_sizes());
    let f2 = PackageModel::new(Package::Fftw2);
    let mut t = Table::new(
        &format!("{name} — optimized {} (PFFT-FPM-PAD) vs unoptimized FFTW-2.1.5", pkg.name()),
        &["N", "speedup vs FFTW-2.1.5"],
    );
    let mut speedups = Vec::new();
    let mut f2_wins = 0usize;
    let mut opt_mflops_sum = 0.0;
    let mut f2_mflops_sum = 0.0;
    for p in &c.points {
        // fftw2 basic time priced identically to other basic runs
        let t_f2 = app_flops(p.n) / (f2.speed(p.n) * 1e6) + 2.0 * transpose_time(p.n);
        let sp = t_f2 / p.t_pad;
        speedups.push(sp);
        if sp < 1.0 {
            f2_wins += 1;
        }
        opt_mflops_sum += p.mflops(p.t_pad);
        f2_mflops_sum += app_flops(p.n) / t_f2 / 1e6;
        t.row(vec![p.n.to_string(), fnum(sp, 3)]);
    }
    t.write_csv(&ctx.out_dir.join(format!("{name}.csv"))).map_err(|e| e.to_string())?;
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let k = c.points.len() as f64;
    Ok(format!(
        "== {name}: optimized {} vs unoptimized FFTW-2.1.5 ==\n  avg speedup {:.2}x (paper: {}), FFTW-2.1.5 still wins {}/{} sizes\n  avg MFLOPs: optimized {} {:.0} vs FFTW-2.1.5 {:.0}\n{}",
        pkg.name(),
        avg,
        if pkg == Package::Fftw3 { "1.2x" } else { "1.7x" },
        f2_wins,
        c.points.len(),
        pkg.name(),
        opt_mflops_sum / k,
        f2_mflops_sum / k,
        crate::figures::profiles::decimated_view(&t, 12)
    ))
}

/// Ablation (DESIGN.md §Perf): paper-ratio vs exact-flops pad cost model.
pub fn pad_ablation(ctx: &Ctx) -> Result<String, String> {
    use crate::coordinator::pad::determine_pad_length;
    use crate::simulator::fpm::SimTestbed;
    use crate::simulator::vexec::PAD_WINDOW;

    let mut t = Table::new(
        "pad-ablation — PaperRatio vs ExactFlops pad selection",
        &["package", "N", "d1", "pad(paper)", "pad(exact)", "agree"],
    );
    let mut agree = 0usize;
    let mut total = 0usize;
    for pkg in [Package::Fftw3, Package::Mkl] {
        let tb = SimTestbed::paper_best(pkg);
        for &n in ctx.campaign_sizes().iter().step_by(23).take(20) {
            let curves = tb.plane_sections(n);
            let Ok(part) = crate::coordinator::partition::hpopta(&curves, n - n % 128) else {
                continue;
            };
            let d1 = part.d[0].max(128);
            let col = tb.column_section(1, d1, n, PAD_WINDOW);
            let a = determine_pad_length(&col, d1, n, PadCost::PaperRatio);
            let b = determine_pad_length(&col, d1, n, PadCost::ExactFlops);
            total += 1;
            if a.n_padded == b.n_padded {
                agree += 1;
            }
            t.row(vec![
                pkg.name().to_string(),
                n.to_string(),
                d1.to_string(),
                a.n_padded.to_string(),
                b.n_padded.to_string(),
                (a.n_padded == b.n_padded).to_string(),
            ]);
        }
    }
    t.write_csv(&ctx.out_dir.join("pad_ablation.csv")).map_err(|e| e.to_string())?;
    Ok(format!(
        "== pad-ablation: cost models agree on {agree}/{total} cases ==\n{}",
        t.render()
    ))
}

/// §V-F-style summary over an arbitrary campaign (re-exported for the
/// summary figure).
pub fn range_summary(c: &Campaign, lo: usize, hi: usize) -> CampaignSummary {
    CampaignSummary::for_range(&c.points, lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx() -> Ctx {
        let mut c = Ctx::new(Path::new("/tmp/hclfft_speedups"), true);
        c.decimate = 64; // keep debug-mode tests fast
        c
    }

    #[test]
    fn fig15_speedup_csv() {
        let s = speedups(&ctx(), "figtest15", Package::Fftw3, Series::Both).unwrap();
        assert!(s.contains("FPM avg"));
        let csv = std::fs::read_to_string("/tmp/hclfft_speedups/figtest15.csv").unwrap();
        assert!(csv.lines().next().unwrap().contains("PFFT-FPM-PAD"));
    }

    #[test]
    fn fig16_only_improved_sizes() {
        let _ = speedups(&ctx(), "figtest16", Package::Fftw3, Series::PadImprovedOnly).unwrap();
        let csv = std::fs::read_to_string("/tmp/hclfft_speedups/figtest16.csv").unwrap();
        for line in csv.lines().skip(1) {
            let sp: f64 = line.split(',').nth(1).unwrap().parse().unwrap();
            assert!(sp > 1.0, "{line}");
        }
    }

    #[test]
    fn fig22_times_positive() {
        let _ = times(&ctx(), "figtest22", Package::Mkl, Series::Both).unwrap();
        let csv = std::fs::read_to_string("/tmp/hclfft_speedups/figtest22.csv").unwrap();
        for line in csv.lines().skip(1) {
            for v in line.split(',').skip(1) {
                assert!(v.parse::<f64>().unwrap() > 0.0);
            }
        }
    }

    #[test]
    fn fig26_vs_fftw2() {
        let s = vs_fftw2(&ctx(), "figtest26", Package::Mkl).unwrap();
        assert!(s.contains("vs unoptimized FFTW-2.1.5"));
        assert!(s.contains("avg speedup"));
    }
}
