//! §V-F summary — the paper's headline statistics table, reproduced
//! side by side with the published values.

use crate::figures::Ctx;
use crate::simulator::vexec::{Campaign, CampaignSummary};
use crate::simulator::Package;
use crate::util::table::{fnum, Table};

/// Published values from the paper's abstract + §V-F (for the ranges, the
/// paper gives mid-range numbers explicitly).
struct PaperClaims {
    fpm_avg: f64,
    fpm_max: f64,
    pad_avg: f64,
    pad_max: f64,
    mid_fpm_avg: f64,
    mid_pad_avg: f64,
}

fn claims(pkg: Package) -> PaperClaims {
    match pkg {
        Package::Fftw3 => PaperClaims {
            fpm_avg: 1.9,
            fpm_max: 6.8,
            pad_avg: 2.0,
            pad_max: 9.4,
            mid_fpm_avg: 2.7,
            mid_pad_avg: 3.0,
        },
        Package::Mkl => PaperClaims {
            fpm_avg: 1.3,
            fpm_max: 2.0,
            pad_avg: 1.4,
            pad_max: 5.9,
            mid_fpm_avg: 1.4,
            mid_pad_avg: 2.7,
        },
        Package::Fftw2 => unreachable!("fftw2 is never optimized in the paper"),
    }
}

pub fn generate(ctx: &Ctx) -> Result<String, String> {
    let mut out = String::from("== summary — §V-F reproduction vs published ==\n");
    let mut t = Table::new(
        "summary",
        &["package", "metric", "published", "reproduced"],
    );
    for pkg in [Package::Fftw3, Package::Mkl] {
        let c = Campaign::run(pkg, &ctx.campaign_sizes());
        let s = c.summary();
        let mid = CampaignSummary::for_range(&c.points, 10_000, 33_000);
        let low = CampaignSummary::for_range(&c.points, 0, 10_000);
        let high = CampaignSummary::for_range(&c.points, 33_000, usize::MAX);
        let p = claims(pkg);
        let rows: Vec<(String, f64, f64)> = vec![
            ("PFFT-FPM avg speedup".into(), p.fpm_avg, s.avg_speedup_fpm),
            ("PFFT-FPM max speedup".into(), p.fpm_max, s.max_speedup_fpm),
            ("PFFT-FPM-PAD avg speedup".into(), p.pad_avg, s.avg_speedup_pad),
            ("PFFT-FPM-PAD max speedup".into(), p.pad_max, s.max_speedup_pad),
            ("mid-range FPM avg".into(), p.mid_fpm_avg, mid.avg_speedup_fpm),
            ("mid-range PAD avg".into(), p.mid_pad_avg, mid.avg_speedup_pad),
            ("low-range FPM avg (paper: ~1, 'not significant')".into(), 1.0, low.avg_speedup_fpm),
            ("high-range FPM avg (paper: 'still good')".into(), f64::NAN, high.avg_speedup_fpm),
        ];
        for (metric, published, got) in rows {
            t.row(vec![
                pkg.name().to_string(),
                metric,
                if published.is_nan() { "-".into() } else { fnum(published, 2) },
                fnum(got, 2),
            ]);
        }
    }
    t.write_csv(&ctx.out_dir.join("summary.csv")).map_err(|e| e.to_string())?;
    out.push_str(&t.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn summary_renders_both_packages() {
        let mut ctx = Ctx::new(Path::new("/tmp/hclfft_summary"), true);
        ctx.decimate = 64;
        let s = generate(&ctx).unwrap();
        assert!(s.contains("FFTW-3.3.7"));
        assert!(s.contains("Intel MKL FFT"));
        assert!(s.contains("PFFT-FPM-PAD max speedup"));
        assert!(Path::new("/tmp/hclfft_summary/summary.csv").exists());
    }
}
