//! Figures 7-8 — ASCII illustrations of PFFT-LB / PFFT-FPM on the
//! paper's N=16, p=4 example (including the Figure-8 distribution
//! d = {5, 3, 2, 6}), traced against a real execution of the drivers.

use crate::coordinator::engine::NativeEngine;
use crate::coordinator::group::{row_offsets, GroupConfig};
use crate::coordinator::pfft::{pfft_fpm, pfft_lb};
use crate::dft::{naive_dft2d, SignalMatrix};

fn row_map(d: &[usize], n: usize) -> String {
    let offsets = row_offsets(d);
    let mut out = String::new();
    for (i, w) in d.iter().enumerate() {
        for r in offsets[i]..offsets[i] + w {
            out.push_str(&format!(
                "  row {r:>2}  P{:<2} {}\n",
                i + 1,
                "·".repeat(n)
            ));
        }
    }
    out
}

pub fn pfft_lb_illustration() -> String {
    let n = 16;
    let cfg = GroupConfig::new(4, 1);
    let orig = SignalMatrix::random(n, n, 7);
    let mut m = orig.clone();
    let rep = pfft_lb(&NativeEngine, &mut m, cfg, 4).expect("pfft-lb");
    let want = naive_dft2d(&orig);
    let err = m.max_abs_diff(&want) / want.norm().max(1.0);
    format!(
        "== fig7 — PFFT-LB, N=16, p=4 (each gets N/p = 4 rows) ==\n\
         (a) row 1D-FFTs on the partition:\n{}\
         (b) transpose  (c) row 1D-FFTs again  (d) transpose\n\
         distribution d = {:?}; verified vs naive 2D-DFT, rel err {err:.2e}\n",
        row_map(&rep.d, n),
        rep.d
    )
}

pub fn pfft_fpm_illustration() -> String {
    let n = 16;
    let d = vec![5usize, 3, 2, 6]; // the paper's Figure 8 distribution
    let orig = SignalMatrix::random(n, n, 8);
    let mut m = orig.clone();
    let rep = pfft_fpm(&NativeEngine, &mut m, &d, 1, 4).expect("pfft-fpm");
    let want = naive_dft2d(&orig);
    let err = m.max_abs_diff(&want) / want.norm().max(1.0);
    format!(
        "== fig8 — PFFT-FPM, N=16, p=4, load-imbalanced d = {{5,3,2,6}} ==\n\
         (a) row 1D-FFTs on the FPM partition:\n{}\
         (b) transpose  (c) row 1D-FFTs again  (d) transpose\n\
         distribution d = {:?}; verified vs naive 2D-DFT, rel err {err:.2e}\n",
        row_map(&rep.d, n),
        rep.d
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_balanced_rows() {
        let s = pfft_lb_illustration();
        assert!(s.contains("d = [4, 4, 4, 4]"));
        assert!(s.contains("rel err"));
        // correctness embedded in the figure: error must be tiny
        let err: f64 = s.split("rel err ").nth(1).unwrap().trim().parse().unwrap();
        assert!(err < 1e-9);
    }

    #[test]
    fn fig8_paper_distribution() {
        let s = pfft_fpm_illustration();
        assert!(s.contains("d = [5, 3, 2, 6]"));
        assert_eq!(s.matches("P1").count(), 5);
        assert_eq!(s.matches("P4").count(), 6);
    }
}
