//! Extension figures (beyond the paper's 26): the future-work items and
//! baselines this repo adds — dynamic-scheduler ablation, cluster strong
//! scaling, the time/energy Pareto front, and the 3D-DFT demo.

use crate::coordinator::dynamic::dynamic_virtual_time;
use crate::coordinator::energy::pareto_front;
use crate::coordinator::fpm::Curve;
use crate::coordinator::partition::hpopta;
use crate::figures::Ctx;
use crate::simulator::cluster::strong_scaling;
use crate::simulator::fpm::SimTestbed;
use crate::simulator::Package;
use crate::util::table::{fnum, Table};

/// ext-dynamic: model-based static (HPOPTA) vs dynamic work-stealing on
/// the virtual testbed — quantifies the value of the model.
pub fn dynamic_ablation(ctx: &Ctx) -> Result<String, String> {
    use crate::simulator::vexec::{simulate_size, transpose_time};
    let tb = SimTestbed::paper_best(Package::Mkl);
    let mut t = Table::new(
        "ext-dynamic — dynamic work-stealing vs PFFT-FPM / PFFT-FPM-PAD (MKL testbed)",
        &["N", "t dynamic (s)", "t PFFT-FPM (s)", "t PFFT-FPM-PAD (s)", "PAD gain %"],
    );
    // regime where the 128-grid gives static planning freedom (below
    // ~p·512 rows the grid floor lets chunked dynamic out-split static —
    // a measurement-granularity artifact, not a scheduling insight)
    let sizes: Vec<usize> =
        ctx.campaign_sizes().into_iter().filter(|&n| n >= 5_000).step_by(17).take(24).collect();
    let mut fpm_gains = Vec::new();
    let mut pad_gains = Vec::new();
    for &n in &sizes {
        let curves = tb.plane_sections(n);
        let n_grid = n - n % 128;
        if hpopta(&curves, n_grid).is_err() {
            continue;
        }
        let pt = simulate_size(&tb, n);
        // dynamic: best of two chunk sizes, same transpose costs, same
        // flops basis (seconds per row at the group's chunk-size speed)
        let fpr = 2.5 * n as f64 * (n as f64).log2() / 1e6;
        let t_dyn_phase = dynamic_virtual_time(&curves, n_grid, 128, fpr)
            .min(dynamic_virtual_time(&curves, n_grid, 512, fpr));
        let t_dyn = 2.0 * t_dyn_phase + 2.0 * transpose_time(n);
        fpm_gains.push(100.0 * (1.0 - pt.t_fpm / t_dyn));
        pad_gains.push(100.0 * (1.0 - pt.t_pad / t_dyn));
        t.row(vec![
            n.to_string(),
            fnum(t_dyn, 3),
            fnum(pt.t_fpm, 3),
            fnum(pt.t_pad, 3),
            fnum(100.0 * (1.0 - pt.t_pad / t_dyn), 1),
        ]);
    }
    t.write_csv(&ctx.out_dir.join("ext_dynamic.csv")).map_err(|e| e.to_string())?;
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    Ok(format!(
        "== ext-dynamic: dynamic scheduling vs model-based ==\n  \
         PFFT-FPM vs dynamic: mean gain {:.1}% (chunked dynamic dodges the same\n  \
         x-keyed drops static does — competitive, as expected); PFFT-FPM-PAD vs\n  \
         dynamic: mean gain {:.1}% — padding dodges the y-keyed drops no runtime\n  \
         scheduler can, which is the model's unique value (DESIGN.md §6)\n{}",
        mean(&fpm_gains),
        mean(&pad_gains),
        t.render()
    ))
}

/// ext-cluster: strong scaling of the distributed 2D-DFT, homogeneous
/// and heterogeneous clusters.
pub fn cluster_scaling(ctx: &Ctx) -> Result<String, String> {
    let n = 24_704;
    let counts = [1usize, 2, 4, 8, 16];
    let mut t = Table::new(
        "ext-cluster — strong scaling, N = 24704 (MKL nodes)",
        &["nodes", "homog t_fpm (s)", "homog speedup", "hetero t_fpm", "hetero t_balanced", "fpm gain %"],
    );
    let homog = strong_scaling(Package::Mkl, n, &counts, 0.0);
    let hetero = strong_scaling(Package::Mkl, n, &counts, 0.4);
    for (h, het) in homog.iter().zip(&hetero) {
        t.row(vec![
            h.nodes.to_string(),
            fnum(h.t_fpm, 3),
            fnum(h.speedup_vs_single, 2),
            fnum(het.t_fpm, 3),
            fnum(het.t_balanced, 3),
            fnum(100.0 * (1.0 - het.t_fpm / het.t_balanced), 1),
        ]);
    }
    t.write_csv(&ctx.out_dir.join("ext_cluster.csv")).map_err(|e| e.to_string())?;
    Ok(t.render())
}

/// ext-energy: time/energy Pareto front on synthetic energy surfaces
/// derived from the MKL testbed (power grows with group utilization).
pub fn energy_pareto(ctx: &Ctx) -> Result<String, String> {
    let tb = SimTestbed::paper_best(Package::Mkl);
    let n = 12_800;
    let speed = tb.plane_sections(n);
    // synthetic energy: E(x) = t(x) · P(x), with active power rising in
    // the row count (more cache/DRAM traffic per unit time)
    let energy: Vec<Curve> = speed
        .iter()
        .map(|c| {
            let joules: Vec<f64> = c
                .xs
                .iter()
                .zip(&c.speeds)
                .map(|(&x, &s)| {
                    let time = x as f64 / s;
                    let watts = 120.0 + 90.0 * (x as f64 / n as f64);
                    time * watts
                })
                .collect();
            Curve::new(c.xs.clone(), joules)
        })
        .collect();
    let front = pareto_front(&speed, &energy, n - n % 128).map_err(|e| e.to_string())?;
    let mut t = Table::new(
        "ext-energy — time/energy Pareto front, N = 12800 (MKL testbed)",
        &["makespan", "energy (rel J)", "d"],
    );
    for pt in &front {
        t.row(vec![fnum(pt.makespan, 3), fnum(pt.energy, 2), format!("{:?}", pt.d)]);
    }
    t.write_csv(&ctx.out_dir.join("ext_energy.csv")).map_err(|e| e.to_string())?;
    Ok(format!(
        "== ext-energy: {} Pareto-optimal (time, energy) points ==\n{}",
        front.len(),
        t.render()
    ))
}

/// ext-3d: real (measured) 3D-DFT through the slab-decomposed
/// coordinator, verified against the serial 3D transform.
pub fn dft3d_demo(ctx: &Ctx) -> Result<String, String> {
    use crate::coordinator::engine::NativeEngine;
    use crate::coordinator::pfft3d::{pfft_fpm_3d, pfft_lb_3d};
    use crate::dft::dft3d::{dft3d, SignalCube};
    use crate::dft::fft::Direction;

    let mut t = Table::new(
        "ext-3d — PFFT-FPM-3D (measured on this host)",
        &["n^3", "t serial (s)", "t slab p=2 (s)", "rel err"],
    );
    for &n in &[16usize, 32, 48] {
        let orig = SignalCube::random(n, n as u64);
        let mut serial = orig.clone();
        let t0 = std::time::Instant::now();
        dft3d(&mut serial, Direction::Forward, 1);
        let t_serial = t0.elapsed().as_secs_f64();

        let mut slab = orig.clone();
        let t0 = std::time::Instant::now();
        let d = vec![n / 2, n - n / 2];
        pfft_fpm_3d(&NativeEngine, &mut slab, &d, 1, 16).map_err(|e| e.to_string())?;
        let t_slab = t0.elapsed().as_secs_f64();

        let err = slab.max_abs_diff(&serial) / serial.norm().max(1.0);
        t.row(vec![
            format!("{n}^3"),
            fnum(t_serial, 4),
            fnum(t_slab, 4),
            format!("{err:.2e}"),
        ]);
        // keep the balanced path exercised too
        let mut lb = orig.clone();
        pfft_lb_3d(&NativeEngine, &mut lb, 2, 1, 16).map_err(|e| e.to_string())?;
    }
    t.write_csv(&ctx.out_dir.join("ext_3d.csv")).map_err(|e| e.to_string())?;
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx() -> Ctx {
        let mut c = Ctx::new(Path::new("/tmp/hclfft_ext"), true);
        c.decimate = 64;
        c
    }

    #[test]
    fn dynamic_ablation_static_wins_on_average() {
        let s = dynamic_ablation(&ctx()).unwrap();
        // PAD must beat the best dynamic on average (y-drop dodging)
        let pad_gain: f64 = s
            .lines()
            .find(|l| l.contains("dynamic: mean gain") && l.contains("padding"))
            .or_else(|| s.lines().find(|l| l.contains("PFFT-FPM-PAD vs")))
            .map(|_| {
                // parse the second "mean gain X%" occurrence
                let mut it = s.match_indices("mean gain ");
                let _ = it.next();
                let (idx, _) = it.next().expect("second gain");
                s[idx + 10..].split('%').next().unwrap().trim().parse().unwrap()
            })
            .expect("gain line");
        assert!(pad_gain > 0.0, "PAD should beat dynamic: {pad_gain}");
    }

    #[test]
    fn cluster_scaling_renders() {
        let s = cluster_scaling(&ctx()).unwrap();
        assert!(s.contains("nodes"));
        assert!(Path::new("/tmp/hclfft_ext/ext_cluster.csv").exists());
    }

    #[test]
    fn energy_front_nonempty() {
        let s = energy_pareto(&ctx()).unwrap();
        assert!(s.contains("Pareto-optimal"));
    }

    #[test]
    fn dft3d_demo_verifies() {
        let s = dft3d_demo(&ctx()).unwrap();
        for line in s.lines().filter(|l| l.contains("e-")) {
            let err: f64 = line.split_whitespace().last().unwrap().parse().unwrap();
            assert!(err < 1e-10, "{line}");
        }
    }
}
