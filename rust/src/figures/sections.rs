//! Figures 9-14 — FPM geometry: plane sections, the HPOPTA partition of
//! the paper's N=24704 MKL example, column sections, pad lengths, and
//! the full speed surfaces.

use crate::coordinator::pad::{determine_pad_length, PadCost};
use crate::coordinator::partition::hpopta;
use crate::figures::Ctx;
use crate::simulator::fpm::SimTestbed;
use crate::simulator::vexec::PAD_WINDOW;
use crate::simulator::Package;
use crate::util::table::{fnum, Table};

/// The paper's running example size (Figures 9-12).
pub const EXAMPLE_N: usize = 24_704;

/// Fig 9: the two MKL 18-thread groups' speed functions sectioned by the
/// plane y = N = 24704.
pub fn plane_sections(ctx: &Ctx) -> Result<String, String> {
    let tb = SimTestbed::paper_best(Package::Mkl);
    let curves = tb.plane_sections(EXAMPLE_N);
    let mut t = Table::new(
        "fig9 — MKL speed functions sectioned by plane y = N = 24704",
        &["x (rows)", "group1 MFLOPs", "group2 MFLOPs"],
    );
    for (k, &x) in curves[0].xs.iter().enumerate() {
        t.row(vec![x.to_string(), fnum(curves[0].speeds[k], 1), fnum(curves[1].speeds[k], 1)]);
    }
    t.write_csv(&ctx.out_dir.join("fig9.csv")).map_err(|e| e.to_string())?;
    Ok(format!(
        "== fig9 — plane section y=24704, 2 groups of 18 threads ==\n  {} grid points per curve\n{}",
        curves[0].len(),
        crate::figures::profiles::decimated_view(&t, 12)
    ))
}

/// Fig 10: HPOPTA applied to the sections → the paper's imbalanced
/// distribution (theirs: d = (11648, 13056)).
pub fn hpopta_partition(ctx: &Ctx) -> Result<String, String> {
    let tb = SimTestbed::paper_best(Package::Mkl);
    let curves = tb.plane_sections(EXAMPLE_N);
    let part = hpopta(&curves, EXAMPLE_N).map_err(|e| e.to_string())?;
    let balanced = crate::coordinator::partition::balanced(2, EXAMPLE_N);
    let bal_makespan = crate::coordinator::partition::predict_makespan(&curves, &balanced.d);
    let mut t = Table::new(
        "fig10 — HPOPTA distribution for N = 24704",
        &["group", "d[i] (rows)", "share %"],
    );
    for (i, &di) in part.d.iter().enumerate() {
        t.row(vec![
            format!("group{}", i + 1),
            di.to_string(),
            fnum(100.0 * di as f64 / EXAMPLE_N as f64, 2),
        ]);
    }
    t.write_csv(&ctx.out_dir.join("fig10.csv")).map_err(|e| e.to_string())?;
    Ok(format!(
        "{}  paper's example: d = (11648, 13056); ours: d = ({}, {})\n  optimal makespan {:.4} vs balanced {:.4} (gain {:.1}%)\n",
        t.render(),
        part.d[0],
        part.d[1],
        part.makespan,
        bal_makespan,
        100.0 * (1.0 - part.makespan / bal_makespan)
    ))
}

/// Fig 11: column sections x = d_i (speed vs y keeping x constant).
pub fn column_sections(ctx: &Ctx) -> Result<String, String> {
    let tb = SimTestbed::paper_best(Package::Mkl);
    let curves = tb.plane_sections(EXAMPLE_N);
    let part = hpopta(&curves, EXAMPLE_N).map_err(|e| e.to_string())?;
    let mut t = Table::new(
        "fig11 — column sections x = d[i] (speed vs y)",
        &["y (row length)", "group1 @ x=d1", "group2 @ x=d2"],
    );
    let c1 = tb.column_section(1, part.d[0], EXAMPLE_N, PAD_WINDOW);
    let c2 = tb.column_section(2, part.d[1], EXAMPLE_N, PAD_WINDOW);
    for (k, &y) in c1.xs.iter().enumerate() {
        let s2 = c2.speed_at(y).unwrap_or(f64::NAN);
        t.row(vec![y.to_string(), fnum(c1.speeds[k], 1), fnum(s2, 1)]);
    }
    t.write_csv(&ctx.out_dir.join("fig11.csv")).map_err(|e| e.to_string())?;
    Ok(format!("{}", crate::figures::profiles::decimated_view(&t, 16)))
}

/// Fig 12: pad lengths determined from the column sections
/// (paper: N_padded = 24960 for both groups).
pub fn pad_lengths(ctx: &Ctx) -> Result<String, String> {
    let tb = SimTestbed::paper_best(Package::Mkl);
    let curves = tb.plane_sections(EXAMPLE_N);
    let part = hpopta(&curves, EXAMPLE_N).map_err(|e| e.to_string())?;
    let mut t = Table::new(
        "fig12 — pad lengths from the FPM column sections (N = 24704)",
        &["group", "d[i]", "N_padded", "predicted gain %"],
    );
    for (i, &di) in part.d.iter().enumerate() {
        let col = tb.column_section(i + 1, di, EXAMPLE_N, PAD_WINDOW);
        let dec = determine_pad_length(&col, di, EXAMPLE_N, PadCost::PaperRatio);
        t.row(vec![
            format!("group{}", i + 1),
            di.to_string(),
            dec.n_padded.to_string(),
            fnum(100.0 * dec.n_padded_gain(), 1),
        ]);
    }
    t.write_csv(&ctx.out_dir.join("fig12.csv")).map_err(|e| e.to_string())?;
    Ok(format!("{}  paper's example pads to 24960 for both groups\n", t.render()))
}

/// Figs 13-14: full speed surfaces (decimated grid; TSV dump per group).
pub fn full_surface(ctx: &Ctx, name: &str, pkg: Package) -> Result<String, String> {
    let tb = SimTestbed::paper_best(pkg);
    // surface grids are big: decimate by 8 (full) / more (quick)
    let decim = 8 * ctx.decimate.max(1);
    let mut out = format!("== {name} — full speed surface: {} ==\n", pkg.name());
    for g in 1..=tb.cfg.p.min(2) {
        let s = tb.full_surface(g, decim);
        let path = ctx.out_dir.join(format!("{name}_group{g}.tsv"));
        s.write_tsv(&path).map_err(|e| e.to_string())?;
        out.push_str(&format!(
            "  group{g}: {} measured points (memory-capped grid), dumped to {}\n",
            s.measured_points(),
            path.display()
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx() -> Ctx {
        Ctx::new(Path::new("/tmp/hclfft_sections"), true)
    }

    #[test]
    fn fig9_two_curves() {
        let s = plane_sections(&ctx()).unwrap();
        assert!(s.contains("plane section"));
        assert!(Path::new("/tmp/hclfft_sections/fig9.csv").exists());
    }

    #[test]
    fn fig10_imbalanced_and_optimal() {
        let s = hpopta_partition(&ctx()).unwrap();
        assert!(s.contains("HPOPTA"));
        // the distribution must sum to N (printed shares ~100%)
        assert!(s.contains("group1") && s.contains("group2"));
    }

    #[test]
    fn fig12_pads_at_or_above_n() {
        let s = pad_lengths(&ctx()).unwrap();
        for line in s.lines().filter(|l| l.trim_start().starts_with("group")) {
            let cols: Vec<&str> = line.split_whitespace().collect();
            if cols[0] == "group" {
                continue; // header row
            }
            let padded: usize = cols[2].parse().unwrap();
            assert!(padded >= EXAMPLE_N, "{line}");
        }
    }

    #[test]
    fn fig13_surface_dump() {
        let s = full_surface(&ctx(), "figtest13", Package::Fftw3).unwrap();
        assert!(s.contains("measured points"));
        assert!(Path::new("/tmp/hclfft_sections/figtest13_group1.tsv").exists());
    }
}
