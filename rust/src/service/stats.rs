//! Service statistics — throughput, latency percentiles, queue depth,
//! and the planning/wisdom counters the acceptance criteria expose.
//!
//! Built on [`crate::stats`]: the latency summary reuses
//! [`crate::stats::summary`] and the MFLOPs column uses the harness's
//! paper-formula flop counts, so service numbers are directly comparable
//! with the bench suites.

use std::sync::Mutex;

use crate::stats::summary;
use crate::util::table::{fnum, Table};

/// Monotonic counters + samples, updated by workers under one lock
/// (updates are tiny compared to a 2D-DFT execution).
#[derive(Debug, Default)]
pub struct StatsCollector {
    inner: Mutex<Inner>,
}

#[derive(Clone, Debug, Default)]
struct Inner {
    latencies_s: Vec<f64>,
    queue_waits_s: Vec<f64>,
    /// per-batch |predicted - actual| / actual (model calibration)
    calib_errs: Vec<f64>,
    flops: f64,
    completed: u64,
    failed: u64,
    rejected: u64,
    shed: u64,
    planning_events: u64,
    wisdom_hits: u64,
    drift_events: u64,
    batches: u64,
    batched_requests: u64,
    max_batch: usize,
    peak_queue_depth: usize,
    /// high-water marks within the current phase window (reset by
    /// [`StatsCollector::mark`]; maxima cannot be recovered by
    /// subtraction like the counters)
    win_max_batch: usize,
    win_peak_queue_depth: usize,
    /// window start for [`StatsCollector::since_mark`] phase snapshots
    mark: Mark,
}

/// Counter values at the last [`StatsCollector::mark`] call — lets
/// serve-bench report cold and warm phases separately.
#[derive(Clone, Copy, Debug, Default)]
struct Mark {
    lat_idx: usize,
    wait_idx: usize,
    calib_idx: usize,
    flops: f64,
    completed: u64,
    failed: u64,
    rejected: u64,
    shed: u64,
    planning_events: u64,
    wisdom_hits: u64,
    drift_events: u64,
    batches: u64,
    batched_requests: u64,
    at_s: f64,
}

impl StatsCollector {
    pub fn new() -> StatsCollector {
        StatsCollector::default()
    }

    pub fn record_completion(&self, latency_s: f64, queue_wait_s: f64, flops: f64) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_s.push(latency_s);
        g.queue_waits_s.push(queue_wait_s);
        g.flops += flops;
        g.completed += 1;
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One request shed by overload backpressure (the serve front end's
    /// bounded admission queue turned it away).
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// Lifetime drift-event count without building a full snapshot.
    pub fn drift_events(&self) -> u64 {
        self.inner.lock().unwrap().drift_events
    }

    pub fn record_planning_event(&self) {
        self.inner.lock().unwrap().planning_events += 1;
    }

    pub fn record_wisdom_hit(&self) {
        self.inner.lock().unwrap().wisdom_hits += 1;
    }

    pub fn record_drift(&self) {
        self.inner.lock().unwrap().drift_events += 1;
    }

    /// One batch's model-calibration error: |predicted - actual| / actual.
    pub fn record_calibration(&self, rel_err: f64) {
        if rel_err.is_finite() {
            self.inner.lock().unwrap().calib_errs.push(rel_err);
        }
    }

    pub fn record_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batched_requests += size as u64;
        g.max_batch = g.max_batch.max(size);
        g.win_max_batch = g.win_max_batch.max(size);
    }

    pub fn observe_queue_depth(&self, depth: usize) {
        let mut g = self.inner.lock().unwrap();
        g.peak_queue_depth = g.peak_queue_depth.max(depth);
        g.win_peak_queue_depth = g.win_peak_queue_depth.max(depth);
    }

    /// Consistent lifetime snapshot; `wall_s` is the observation window
    /// for throughput/MFLOPs rates.
    pub fn snapshot(&self, wall_s: f64) -> ServiceStats {
        let g = self.inner.lock().unwrap();
        let (mb, pd) = (g.max_batch, g.peak_queue_depth);
        stats_over(&g, Mark::default(), wall_s, mb, pd)
    }

    /// Start a phase window: subsequent [`StatsCollector::since_mark`]
    /// snapshots cover only what happened after this call (serve-bench's
    /// cold vs warm phases).
    pub fn mark(&self, now_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.mark = Mark {
            lat_idx: g.latencies_s.len(),
            wait_idx: g.queue_waits_s.len(),
            calib_idx: g.calib_errs.len(),
            flops: g.flops,
            completed: g.completed,
            failed: g.failed,
            rejected: g.rejected,
            shed: g.shed,
            planning_events: g.planning_events,
            wisdom_hits: g.wisdom_hits,
            drift_events: g.drift_events,
            batches: g.batches,
            batched_requests: g.batched_requests,
            at_s: now_s,
        };
        g.win_max_batch = 0;
        g.win_peak_queue_depth = 0;
    }

    /// Snapshot of the window since the last [`StatsCollector::mark`]
    /// (whole lifetime when never marked).
    pub fn since_mark(&self, now_s: f64) -> ServiceStats {
        let g = self.inner.lock().unwrap();
        let m = g.mark;
        // before the first mark() the window maxima equal the lifetime
        // maxima (both accumulate from zero)
        let (mb, pd) = (g.win_max_batch, g.win_peak_queue_depth);
        stats_over(&g, m, now_s - m.at_s, mb, pd)
    }
}

/// Compute a [`ServiceStats`] over everything recorded after `mark`.
fn stats_over(
    g: &Inner,
    m: Mark,
    wall_s: f64,
    max_batch: usize,
    peak_depth: usize,
) -> ServiceStats {
    let mut sorted = g.latencies_s[m.lat_idx..].to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lat = summary(&sorted);
    let wait = summary(&g.queue_waits_s[m.wait_idx..]);
    let calib = &g.calib_errs[m.calib_idx..];
    let completed = g.completed - m.completed;
    let wall = wall_s.max(1e-12);
    ServiceStats {
        completed,
        failed: g.failed - m.failed,
        rejected: g.rejected - m.rejected,
        shed: g.shed - m.shed,
        wall_s,
        throughput_rps: completed as f64 / wall,
        mflops: (g.flops - m.flops) / wall / 1e6,
        latency_mean_s: lat.mean,
        latency_p50_s: percentile(&sorted, 0.50),
        latency_p95_s: percentile(&sorted, 0.95),
        latency_p99_s: percentile(&sorted, 0.99),
        latency_max_s: lat.max.max(0.0),
        queue_wait_mean_s: wait.mean,
        planning_events: g.planning_events - m.planning_events,
        wisdom_hits: g.wisdom_hits - m.wisdom_hits,
        drift_events: g.drift_events - m.drift_events,
        calibration_batches: calib.len() as u64,
        calibration_mean_err: summary(calib).mean,
        calibration_last_err: calib.last().copied().unwrap_or(f64::NAN),
        batches: g.batches - m.batches,
        batched_requests: g.batched_requests - m.batched_requests,
        max_batch,
        peak_queue_depth: peak_depth,
    }
}

/// Nearest-rank percentile over an ascending-sorted sample (0 on empty).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Immutable snapshot of the service counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceStats {
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    /// requests turned away by overload backpressure (bounded admission
    /// queue at capacity — see [`crate::serve`])
    pub shed: u64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    /// aggregate paper-formula MFLOPs over the window
    pub mflops: f64,
    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_p99_s: f64,
    pub latency_max_s: f64,
    pub queue_wait_mean_s: f64,
    /// cold plans computed (FPM build + POPTA/HPOPTA + pad search)
    pub planning_events: u64,
    /// requests served from memoized wisdom
    pub wisdom_hits: u64,
    /// online-model drift detections (each invalidated wisdom + replanned)
    pub drift_events: u64,
    /// batches that contributed a calibration sample
    pub calibration_batches: u64,
    /// mean |predicted - actual| / actual over those batches
    pub calibration_mean_err: f64,
    /// most recent batch's calibration error (NaN when none)
    pub calibration_last_err: f64,
    pub batches: u64,
    pub batched_requests: u64,
    pub max_batch: usize,
    pub peak_queue_depth: usize,
}

impl ServiceStats {
    /// Mean coalesced batch size.
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Render the serve-bench report table.
    pub fn render_table(&self, title: &str) -> String {
        let ms = |s: f64| format!("{:.3} ms", s * 1e3);
        let mut t = Table::new(title, &["metric", "value"]);
        t.row(vec!["requests completed".into(), self.completed.to_string()]);
        t.row(vec!["requests failed".into(), self.failed.to_string()]);
        t.row(vec!["requests rejected".into(), self.rejected.to_string()]);
        t.row(vec!["requests shed".into(), self.shed.to_string()]);
        t.row(vec!["wall time".into(), format!("{:.3} s", self.wall_s)]);
        t.row(vec!["throughput".into(), format!("{} req/s", fnum(self.throughput_rps, 2))]);
        t.row(vec!["aggregate speed".into(), format!("{} MFLOPs", fnum(self.mflops, 1))]);
        t.row(vec!["latency mean".into(), ms(self.latency_mean_s)]);
        t.row(vec!["latency p50".into(), ms(self.latency_p50_s)]);
        t.row(vec!["latency p95".into(), ms(self.latency_p95_s)]);
        t.row(vec!["latency p99".into(), ms(self.latency_p99_s)]);
        t.row(vec!["latency max".into(), ms(self.latency_max_s)]);
        t.row(vec!["queue wait mean".into(), ms(self.queue_wait_mean_s)]);
        t.row(vec!["planning events (cold)".into(), self.planning_events.to_string()]);
        t.row(vec!["wisdom hits (warm)".into(), self.wisdom_hits.to_string()]);
        t.row(vec!["model drift events".into(), self.drift_events.to_string()]);
        t.row(vec![
            "model calibration err (mean)".into(),
            if self.calibration_batches == 0 {
                "n/a".into()
            } else {
                format!("{:.1}%", self.calibration_mean_err * 100.0)
            },
        ]);
        t.row(vec![
            "model calibration err (last)".into(),
            if self.calibration_last_err.is_finite() {
                format!("{:.1}%", self.calibration_last_err * 100.0)
            } else {
                "n/a".into()
            },
        ]);
        t.row(vec!["batches dispatched".into(), self.batches.to_string()]);
        t.row(vec!["avg batch size".into(), fnum(self.avg_batch(), 2)]);
        t.row(vec!["max batch size".into(), self.max_batch.to_string()]);
        t.row(vec!["peak queue depth".into(), self.peak_queue_depth.to_string()]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 0.50), 50.0);
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn mark_windows_isolate_phases() {
        let c = StatsCollector::new();
        c.record_completion(0.001, 0.0, 1e6);
        c.record_planning_event();
        c.record_calibration(0.5);
        c.record_batch(8);
        c.observe_queue_depth(12);
        c.mark(1.0);
        c.record_completion(0.002, 0.0, 1e6);
        c.record_wisdom_hit();
        c.record_drift();
        c.record_shed();
        c.record_calibration(0.1);
        c.record_batch(2);
        c.observe_queue_depth(3);
        let warm = c.since_mark(3.0);
        // maxima are per-window, not lifetime
        assert_eq!(warm.max_batch, 2);
        assert_eq!(warm.peak_queue_depth, 3);
        assert_eq!(warm.completed, 1);
        assert_eq!(warm.planning_events, 0);
        assert_eq!(warm.wisdom_hits, 1);
        assert_eq!(warm.drift_events, 1);
        assert_eq!(warm.shed, 1);
        assert_eq!(c.drift_events(), 1);
        assert_eq!(warm.calibration_batches, 1);
        assert!((warm.calibration_mean_err - 0.1).abs() < 1e-12);
        assert!((warm.wall_s - 2.0).abs() < 1e-12);
        assert_eq!(warm.latency_p50_s, 0.002);
        let total = c.snapshot(3.0);
        assert_eq!(total.completed, 2);
        assert_eq!(total.max_batch, 8, "lifetime snapshot keeps the global maxima");
        assert_eq!(total.peak_queue_depth, 12);
        assert_eq!(total.calibration_batches, 2);
        assert!((total.calibration_last_err - 0.1).abs() < 1e-12);
        let table = total.render_table("svc");
        assert!(table.contains("model drift events"));
        assert!(table.contains("model calibration err"));
    }

    #[test]
    fn collector_snapshot_counts() {
        let c = StatsCollector::new();
        for i in 1..=10 {
            c.record_completion(i as f64 / 1000.0, 0.0001, 1e6);
        }
        c.record_planning_event();
        c.record_wisdom_hit();
        c.record_wisdom_hit();
        c.record_batch(4);
        c.record_batch(6);
        c.observe_queue_depth(3);
        c.observe_queue_depth(1);
        let s = c.snapshot(2.0);
        assert_eq!(s.completed, 10);
        assert_eq!(s.throughput_rps, 5.0);
        assert_eq!(s.planning_events, 1);
        assert_eq!(s.wisdom_hits, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.max_batch, 6);
        assert_eq!(s.avg_batch(), 5.0);
        assert_eq!(s.peak_queue_depth, 3);
        assert_eq!(s.latency_p50_s, 0.005);
        assert!((s.mflops - 5.0).abs() < 1e-9);
        let table = s.render_table("svc");
        assert!(table.contains("planning events"));
        assert!(table.contains("throughput"));
    }
}
