//! Service statistics — throughput, latency percentiles, queue depth,
//! and the planning/wisdom counters the acceptance criteria expose.
//!
//! Built on [`crate::stats`]: the latency summary reuses
//! [`crate::stats::summary`] and the MFLOPs column uses the harness's
//! paper-formula flop counts, so service numbers are directly comparable
//! with the bench suites.

use std::sync::Mutex;

use crate::stats::summary;
use crate::util::table::{fnum, Table};

/// Monotonic counters + samples, updated by workers under one lock
/// (updates are tiny compared to a 2D-DFT execution).
#[derive(Debug, Default)]
pub struct StatsCollector {
    inner: Mutex<Inner>,
}

#[derive(Clone, Debug, Default)]
struct Inner {
    latencies_s: Vec<f64>,
    queue_waits_s: Vec<f64>,
    flops: f64,
    completed: u64,
    failed: u64,
    rejected: u64,
    planning_events: u64,
    wisdom_hits: u64,
    batches: u64,
    batched_requests: u64,
    max_batch: usize,
    peak_queue_depth: usize,
}

impl StatsCollector {
    pub fn new() -> StatsCollector {
        StatsCollector::default()
    }

    pub fn record_completion(&self, latency_s: f64, queue_wait_s: f64, flops: f64) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_s.push(latency_s);
        g.queue_waits_s.push(queue_wait_s);
        g.flops += flops;
        g.completed += 1;
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_planning_event(&self) {
        self.inner.lock().unwrap().planning_events += 1;
    }

    pub fn record_wisdom_hit(&self) {
        self.inner.lock().unwrap().wisdom_hits += 1;
    }

    pub fn record_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batched_requests += size as u64;
        g.max_batch = g.max_batch.max(size);
    }

    pub fn observe_queue_depth(&self, depth: usize) {
        let mut g = self.inner.lock().unwrap();
        g.peak_queue_depth = g.peak_queue_depth.max(depth);
    }

    /// Consistent snapshot; `wall_s` is the observation window for
    /// throughput/MFLOPs rates.
    pub fn snapshot(&self, wall_s: f64) -> ServiceStats {
        let g = self.inner.lock().unwrap();
        let mut sorted = g.latencies_s.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let lat = summary(&sorted);
        let wait = summary(&g.queue_waits_s);
        let wall = wall_s.max(1e-12);
        ServiceStats {
            completed: g.completed,
            failed: g.failed,
            rejected: g.rejected,
            wall_s,
            throughput_rps: g.completed as f64 / wall,
            mflops: g.flops / wall / 1e6,
            latency_mean_s: lat.mean,
            latency_p50_s: percentile(&sorted, 0.50),
            latency_p95_s: percentile(&sorted, 0.95),
            latency_p99_s: percentile(&sorted, 0.99),
            latency_max_s: lat.max.max(0.0),
            queue_wait_mean_s: wait.mean,
            planning_events: g.planning_events,
            wisdom_hits: g.wisdom_hits,
            batches: g.batches,
            batched_requests: g.batched_requests,
            max_batch: g.max_batch,
            peak_queue_depth: g.peak_queue_depth,
        }
    }
}

/// Nearest-rank percentile over an ascending-sorted sample (0 on empty).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Immutable snapshot of the service counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceStats {
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    /// aggregate paper-formula MFLOPs over the window
    pub mflops: f64,
    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_p99_s: f64,
    pub latency_max_s: f64,
    pub queue_wait_mean_s: f64,
    /// cold plans computed (FPM build + POPTA/HPOPTA + pad search)
    pub planning_events: u64,
    /// requests served from memoized wisdom
    pub wisdom_hits: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub max_batch: usize,
    pub peak_queue_depth: usize,
}

impl ServiceStats {
    /// Mean coalesced batch size.
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Render the serve-bench report table.
    pub fn render_table(&self, title: &str) -> String {
        let ms = |s: f64| format!("{:.3} ms", s * 1e3);
        let mut t = Table::new(title, &["metric", "value"]);
        t.row(vec!["requests completed".into(), self.completed.to_string()]);
        t.row(vec!["requests failed".into(), self.failed.to_string()]);
        t.row(vec!["requests rejected".into(), self.rejected.to_string()]);
        t.row(vec!["wall time".into(), format!("{:.3} s", self.wall_s)]);
        t.row(vec!["throughput".into(), format!("{} req/s", fnum(self.throughput_rps, 2))]);
        t.row(vec!["aggregate speed".into(), format!("{} MFLOPs", fnum(self.mflops, 1))]);
        t.row(vec!["latency mean".into(), ms(self.latency_mean_s)]);
        t.row(vec!["latency p50".into(), ms(self.latency_p50_s)]);
        t.row(vec!["latency p95".into(), ms(self.latency_p95_s)]);
        t.row(vec!["latency p99".into(), ms(self.latency_p99_s)]);
        t.row(vec!["latency max".into(), ms(self.latency_max_s)]);
        t.row(vec!["queue wait mean".into(), ms(self.queue_wait_mean_s)]);
        t.row(vec!["planning events (cold)".into(), self.planning_events.to_string()]);
        t.row(vec!["wisdom hits (warm)".into(), self.wisdom_hits.to_string()]);
        t.row(vec!["batches dispatched".into(), self.batches.to_string()]);
        t.row(vec!["avg batch size".into(), fnum(self.avg_batch(), 2)]);
        t.row(vec!["max batch size".into(), self.max_batch.to_string()]);
        t.row(vec!["peak queue depth".into(), self.peak_queue_depth.to_string()]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 0.50), 50.0);
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn collector_snapshot_counts() {
        let c = StatsCollector::new();
        for i in 1..=10 {
            c.record_completion(i as f64 / 1000.0, 0.0001, 1e6);
        }
        c.record_planning_event();
        c.record_wisdom_hit();
        c.record_wisdom_hit();
        c.record_batch(4);
        c.record_batch(6);
        c.observe_queue_depth(3);
        c.observe_queue_depth(1);
        let s = c.snapshot(2.0);
        assert_eq!(s.completed, 10);
        assert_eq!(s.throughput_rps, 5.0);
        assert_eq!(s.planning_events, 1);
        assert_eq!(s.wisdom_hits, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.max_batch, 6);
        assert_eq!(s.avg_batch(), 5.0);
        assert_eq!(s.peak_queue_depth, 3);
        assert_eq!(s.latency_p50_s, 0.005);
        assert!((s.mflops - 5.0).abs() < 1e-9);
        let table = s.render_table("svc");
        assert!(table.contains("planning events"));
        assert!(table.contains("throughput"));
    }
}
