//! Model-driven 2D-DFT serving subsystem.
//!
//! Turns the one-shot PFFT drivers into a concurrent server for heavy
//! traffic. Request lifecycle (see README §Serving):
//!
//! 1. **submit** — callers hand an owned [`crate::dft::SignalMatrix`]
//!    wrapped in a [`Dft2dRequest`] to [`Dft2dService::submit`] and get a
//!    [`ResponseHandle`] back.
//! 2. **admit** — the FPM-informed admission check rejects requests whose
//!    predicted cost (from the wisdom store's speed-function-derived
//!    estimate) already exceeds their `deadline_hint`.
//! 3. **batch** — admitted requests coalesce per `(engine, n, direction)`
//!    in a [`sched::BatchQueue`]; dispatch is shortest-predicted-job-first
//!    with a starvation bound.
//! 4. **execute** — a fixed worker pool pops batches; planning artifacts
//!    (POPTA/HPOPTA partition, pad lengths, row-kernel factor schedule,
//!    plan-cache warmup) come from the [`wisdom`] store — computed once
//!    per `(engine, n, p)`, reused forever, persisted as JSON. Forward
//!    transforms run the coalesced [`batch::execute_planned_batch`] —
//!    by default the plan's compiled fused pipeline (one stage DAG
//!    across the whole batch: strided column-FFT tiles instead of
//!    transpose barriers, pads as tile strides; `ServiceConfig::
//!    pipeline` selects the barrier fallback); inverse transforms take
//!    the exact `dft2d` path (padding is forward-only spectral
//!    interpolation). All tiles, row FFTs and (barrier-mode) transposes
//!    execute on the shared [`crate::dft::exec::ExecCtx`] pool with
//!    per-thread scratch arenas — the steady-state hot path spawns no
//!    threads and allocates no scratch planes.
//! 5. **respond** — each request's channel receives the transformed
//!    matrix plus a per-request [`ResponseReport`]; [`stats`] aggregates
//!    throughput, p50/p95/p99 latency, queue depth and the
//!    planning-event counters.
//!
//! **Transform kinds** (PR 5): a [`Dft2dRequest`] declares its
//! [`TransformKind`] — c2c, r2c (real signal in, Hermitian-packed
//! `N×(N/2+1)` half spectrum out) or c2r (the inverse). Batching
//! buckets by `(engine, n, direction, kind)`; wisdom records, FPM
//! surfaces and online-model observation streams are all kind-keyed
//! (real planes run ~2x faster, so their POPTA/HPOPTA partitions and
//! cost estimates are separate artifacts — see [`model_key`]). r2c
//! batches run the stage-DAG real executor
//! ([`crate::coordinator::real`]); c2r takes the exact `irfft2d` path.
//!
//! A **virtual-time path** backs the whole pipeline with the calibrated
//! [`crate::simulator`] instead of a real engine: requests are priced by
//! `simulate_size` and advance a deterministic virtual clock, so
//! scheduling behaviour is testable at paper-scale sizes (N = 24704) in
//! milliseconds.
//!
//! **The model feedback loop** (PR 3): every executed batch is a free
//! measurement. The executor folds its per-request wall time into the
//! engine's [`crate::model::OnlineModel`] at the whole-request point
//! `(x, y) = (2N, N)` (two row phases of N rows each) — and, per
//! phase, the row-stage vs column-stage split of the same batch, so a
//! drift event classifies itself as compute drift (both phases shift)
//! or memory-bandwidth drift (the column stage shifts
//! disproportionately); admission and
//! SPJF costs come from that live model first (wisdom second, flat
//! fallback last), and every response reports predicted-vs-actual so
//! the service's calibration error is observable. When the observation
//! stream contradicts the established estimate (`variation_pct` drift),
//! the affected wisdom partition is invalidated and re-planning runs in
//! the worker — POPTA/HPOPTA and pad selection against the model's
//! refreshed (speed-rescaled) sections. Memory-classified drift
//! additionally invalidates the *measured row-tile widths*
//! ([`crate::dft::exec::calibrate_row_tile`], timed on the cold-plan
//! path and persisted in the wisdom artifact's v4 `tiles` array): a
//! width tuned for the old cache behaviour is exactly what a
//! memory-regime shift makes stale. `save_wisdom` persists the
//! model deltas and drift log next to the plans; virtual backends
//! accept an injected slowdown factor
//! ([`Dft2dService::set_virtual_slowdown`]) so the whole loop is
//! deterministically testable in virtual time.
//!
//! **The engine portfolio** (PR 10): engines are identified by typed
//! [`EngineId`]s (requests still carry the canonical string on the
//! wire) and a service built with [`ServiceBuilder::portfolio`] serves
//! requests addressed to `"portfolio"` by resolving the fastest
//! registered member per `(n, kind)` from the
//! [`PortfolioModel`]'s cost surfaces — *before* bucketing, because
//! batch buckets key on the engine that executes. Drift on the
//! incumbent engine evicts its picks and degrades its surfaces, so the
//! next request at that point re-picks; actual switches land in the
//! [`RepickEvent`] log ([`Dft2dService::portfolio_repicks`]). Surfaces
//! persist in the wisdom artifact (JSON v5).

pub mod batch;
pub mod sched;
pub mod stats;
pub mod wisdom;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::coordinator::engine::{BuiltEngine, EngineId, EngineRegistry, RowFftEngine};
use crate::coordinator::plan::{PhaseTimings, PlannedTransform};
use crate::coordinator::real::execute_real_batch_with_mode;
use crate::dft::fft::Direction;
use crate::dft::pipeline::PipelineMode;
use crate::dft::real::{half_cols, irfft2d_owned_with_mode, TransformKind};
use crate::dft::SignalMatrix;
use crate::model::{
    DriftClass, DriftPolicy, OnlineModel, PerfModel, Phase, PortfolioModel, RepickEvent, SimModel,
    StaticModel,
};
use crate::simulator::Package;
use crate::stats::harness::fft2d_flops;

use sched::{BatchKey, BatchQueue};
use stats::{ServiceStats, StatsCollector};
use wisdom::{PlanningConfig, WisdomRecord, WisdomStore, DEFAULT_MFLOPS};

/// The online model's observation/query point for a whole N×N request:
/// two row phases of N rows of length N (pads are an executor detail
/// folded into the measured time).
pub fn observation_point(n: usize) -> (usize, usize) {
    (2 * n, n)
}

/// The model-store key for an `(engine, kind)` stream. The
/// [`OnlineModel`] keeps **per-kind observation streams**: real (r2c)
/// requests do roughly half the work of c2c requests at the same N, so
/// folding both into one stream would make every estimate wrong for
/// both and fire spurious drift on every kind switch. c2r shares the
/// r2c stream (same plane), exactly as c2c inverse shares c2c.
pub fn model_key(engine: &str, kind: TransformKind) -> String {
    match kind.plan_kind() {
        TransformKind::C2c => engine.to_string(),
        k => format!("{engine}+{}", k.name()),
    }
}

/// Complex-flop work of one request of the given kind (the real path
/// does ~half the kernel work of c2c at the same N).
fn kind_flops(n: usize, kind: TransformKind) -> f64 {
    fft2d_flops(n) * kind.flops_factor()
}

/// Errors surfaced to callers. Every variant carries enough context to
/// diagnose the rejected request (n, kind where applicable) and has a
/// **stable numeric code** ([`ServiceError::code`]) — the wire protocol
/// ships the code + rendered message, so codes must never be renumbered.
#[derive(Clone, Debug, PartialEq)]
pub enum ServiceError {
    UnknownEngine(String),
    BadShape { n: usize, rows: usize, cols: usize, kind: &'static str },
    UnsupportedKind { engine: String, kind: &'static str },
    DeadlineInfeasible { n: usize, kind: &'static str, predicted_s: f64, hint_s: f64 },
    Engine(String),
    ShuttingDown,
    Disconnected,
    /// Load shed: the admission queue is at capacity. `predicted_wait_s`
    /// is the FPM-predicted seconds of work already queued — what the
    /// caller would have waited for before even starting.
    Overloaded { queued: usize, capacity: usize, predicted_wait_s: f64 },
    /// The signal planes exceed the configured admission byte budget.
    PayloadTooLarge { n: usize, kind: &'static str, bytes: usize, max_bytes: usize },
    /// The plane buffer lengths disagree with the declared rows×cols
    /// geometry (previously a worker-side panic).
    BadPayload { n: usize, kind: &'static str, expected: usize, re_len: usize, im_len: usize },
}

impl ServiceError {
    /// Stable numeric code for the wire protocol and logs. Append-only:
    /// new variants take fresh numbers, existing numbers never move.
    pub fn code(&self) -> u16 {
        match self {
            ServiceError::UnknownEngine(_) => 1,
            ServiceError::BadShape { .. } => 2,
            ServiceError::UnsupportedKind { .. } => 3,
            ServiceError::DeadlineInfeasible { .. } => 4,
            ServiceError::Engine(_) => 5,
            ServiceError::ShuttingDown => 6,
            ServiceError::Disconnected => 7,
            ServiceError::Overloaded { .. } => 8,
            ServiceError::PayloadTooLarge { .. } => 9,
            ServiceError::BadPayload { .. } => 10,
        }
    }
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::UnknownEngine(e) => write!(f, "unknown engine `{e}`"),
            ServiceError::BadShape { n, rows, cols, kind } => {
                write!(f, "signal matrix shape {rows}x{cols} does not match a {kind} request of size n={n}")
            }
            ServiceError::UnsupportedKind { engine, kind } => {
                write!(f, "engine `{engine}` does not serve {kind} transforms")
            }
            ServiceError::DeadlineInfeasible { n, kind, predicted_s, hint_s } => write!(
                f,
                "admission rejected ({kind} n={n}): predicted cost {predicted_s:.6}s exceeds deadline hint {hint_s:.6}s"
            ),
            ServiceError::Engine(msg) => write!(f, "engine failure: {msg}"),
            ServiceError::ShuttingDown => write!(f, "service is shutting down"),
            ServiceError::Disconnected => write!(f, "service dropped the request channel"),
            ServiceError::Overloaded { queued, capacity, predicted_wait_s } => write!(
                f,
                "overloaded: {queued} requests queued (capacity {capacity}), predicted wait {predicted_wait_s:.6}s"
            ),
            ServiceError::PayloadTooLarge { n, kind, bytes, max_bytes } => write!(
                f,
                "payload too large ({kind} n={n}): {bytes} bytes exceeds the {max_bytes}-byte admission limit"
            ),
            ServiceError::BadPayload { n, kind, expected, re_len, im_len } => write!(
                f,
                "payload planes disagree with the declared geometry ({kind} n={n}): expected {expected} samples per plane, got re={re_len} im={im_len}"
            ),
        }
    }
}

impl std::error::Error for ServiceError {}

/// One 2D-DFT request over an owned signal matrix.
#[derive(Debug)]
pub struct Dft2dRequest {
    /// problem size (must equal `matrix.rows` unless this is a
    /// virtual-time probe carrying an empty matrix)
    pub n: usize,
    pub matrix: SignalMatrix,
    pub direction: Direction,
    /// what the request transforms: c2c (n×n complex in/out), r2c (n×n
    /// real signal in the `re` plane in, packed n×(n/2+1) half spectrum
    /// out) or c2r (packed in, n×n real out in the `re` plane)
    pub kind: TransformKind,
    /// engine name — the canonical [`EngineId`] spelling ("native",
    /// "sim-mkl", ..., or "portfolio" to let the portfolio model pick);
    /// parsed and validated at submit, kept as the wire-format string
    pub engine: String,
    /// optional latency budget in seconds — the admission policy rejects
    /// the request up front when the FPM-predicted cost already exceeds it
    pub deadline_hint: Option<f64>,
}

impl Dft2dRequest {
    /// Forward transform on the given engine.
    pub fn forward(engine: &str, matrix: SignalMatrix) -> Dft2dRequest {
        Dft2dRequest {
            n: matrix.rows,
            matrix,
            direction: Direction::Forward,
            kind: TransformKind::C2c,
            engine: engine.to_string(),
            deadline_hint: None,
        }
    }

    /// Inverse transform on the given engine.
    pub fn inverse(engine: &str, matrix: SignalMatrix) -> Dft2dRequest {
        Dft2dRequest {
            n: matrix.rows,
            matrix,
            direction: Direction::Inverse,
            kind: TransformKind::C2c,
            engine: engine.to_string(),
            deadline_hint: None,
        }
    }

    /// Real-input forward (r2c) transform: the `n×n` signal lives in the
    /// matrix's `re` plane (`im` is ignored); the response matrix is the
    /// Hermitian-packed `n×(n/2+1)` half spectrum.
    pub fn real_forward(engine: &str, matrix: SignalMatrix) -> Dft2dRequest {
        Dft2dRequest {
            n: matrix.rows,
            matrix,
            direction: Direction::Forward,
            kind: TransformKind::R2c,
            engine: engine.to_string(),
            deadline_hint: None,
        }
    }

    /// Real-output inverse (c2r) transform: `packed` is an `n×(n/2+1)`
    /// half spectrum (what [`Dft2dRequest::real_forward`] returned); the
    /// response matrix is `n×n` with the real signal in its `re` plane
    /// and a zero `im` plane.
    pub fn real_inverse(engine: &str, n: usize, packed: SignalMatrix) -> Dft2dRequest {
        Dft2dRequest {
            n,
            matrix: packed,
            direction: Direction::Inverse,
            kind: TransformKind::C2r,
            engine: engine.to_string(),
            deadline_hint: None,
        }
    }

    /// A virtual-time probe: no signal buffers, just a size — only valid
    /// against virtual backends, where nothing is transformed anyway.
    /// This is how scheduling is exercised at paper-scale N (a real
    /// 24704² complex-double matrix would be ~10 GiB).
    pub fn probe(engine: &str, n: usize) -> Dft2dRequest {
        Dft2dRequest {
            n,
            matrix: SignalMatrix::zeros(0, 0),
            direction: Direction::Forward,
            kind: TransformKind::C2c,
            engine: engine.to_string(),
            deadline_hint: None,
        }
    }

    pub fn with_deadline(mut self, seconds: f64) -> Dft2dRequest {
        self.deadline_hint = Some(seconds);
        self
    }
}

/// Per-request execution report.
#[derive(Clone, Debug)]
pub struct ResponseReport {
    /// the engine that actually executed — for portfolio requests, the
    /// member the portfolio resolved to at admission
    pub engine: EngineId,
    /// rows per abstract processor used
    pub d: Vec<usize>,
    /// padded row length per processor
    pub pads: Vec<usize>,
    pub algorithm: String,
    /// how many requests shared the dispatch (>= 1)
    pub batched_with: usize,
    /// did this dispatch pay a cold planning event?
    pub planned_cold: bool,
    pub queue_wait_s: f64,
    pub latency_s: f64,
    /// model-predicted per-request seconds at dispatch time (the SPJF
    /// weight this batch was scheduled with)
    pub predicted_s: f64,
    /// measured per-request execution seconds (virtual seconds on
    /// virtual backends) — `predicted_s` vs `executed_s` is the
    /// calibration error the model is shrinking
    pub executed_s: f64,
    /// virtual completion timestamp (virtual backends only)
    pub virtual_done_s: Option<f64>,
}

/// The transformed matrix plus its report.
#[derive(Debug)]
pub struct Dft2dResponse {
    pub id: u64,
    pub matrix: SignalMatrix,
    pub report: ResponseReport,
}

/// Blocking handle for one submitted request.
#[derive(Debug)]
pub struct ResponseHandle {
    pub id: u64,
    rx: mpsc::Receiver<Result<Dft2dResponse, ServiceError>>,
}

impl ResponseHandle {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Dft2dResponse, ServiceError> {
        self.rx.recv().unwrap_or(Err(ServiceError::Disconnected))
    }
}

/// Queue-backlog snapshot ([`Dft2dService::backlog`]): how much admitted
/// work a service is holding, priced by the same model estimates SPJF
/// schedules with.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Backlog {
    /// requests queued (not yet popped by a worker)
    pub queued: usize,
    /// Σ model-predicted per-request seconds over those requests
    pub predicted_s: f64,
}

/// Service tunables.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// fixed worker-pool size
    pub workers: usize,
    /// max requests coalesced into one dispatch
    pub max_batch: usize,
    /// seconds after which a waiting bucket preempts cheaper work
    pub starvation_bound_s: f64,
    /// transpose block size for the execution phases (barrier mode)
    pub transpose_block: usize,
    /// fused tile pipeline (default) vs the barrier four-step fallback
    pub pipeline: PipelineMode,
    /// planning knobs (p, t, ε, pad policy, profiling budget)
    pub planning: PlanningConfig,
    /// online-model drift detection knobs
    pub drift: DriftPolicy,
    /// admission byte budget for one request's signal planes (re + im);
    /// `None` admits any size the process can hold
    pub max_payload_bytes: Option<usize>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            max_batch: 8,
            starvation_bound_s: 5.0,
            transpose_block: 64,
            pipeline: PipelineMode::Fused,
            planning: PlanningConfig::default(),
            drift: DriftPolicy::default(),
            max_payload_bytes: None,
        }
    }
}

/// An execution backend: a real row-FFT engine, or the calibrated
/// virtual testbed (deterministic virtual time, no data transformed).
#[derive(Clone)]
enum Backend {
    Real(Arc<dyn RowFftEngine + Send + Sync>),
    Virtual(Package),
}

/// How a finished request reaches its caller: the blocking channel
/// behind [`ResponseHandle`], or a callback (what the [`crate::serve`]
/// front end's tickets ride on). Exactly-once: `send` consumes self.
enum Completion {
    Channel(mpsc::Sender<Result<Dft2dResponse, ServiceError>>),
    Callback(Box<dyn FnOnce(Result<Dft2dResponse, ServiceError>) + Send>),
}

impl Completion {
    fn send(self, r: Result<Dft2dResponse, ServiceError>) {
        match self {
            Completion::Channel(tx) => {
                let _ = tx.send(r);
            }
            Completion::Callback(cb) => cb(r),
        }
    }
}

struct Pending {
    id: u64,
    matrix: SignalMatrix,
    tx: Completion,
    submitted: Instant,
}

struct Inner {
    cfg: ServiceConfig,
    engines: BTreeMap<EngineId, Backend>,
    queue: Mutex<BatchQueue<Pending>>,
    cv: Condvar,
    wisdom: Mutex<WisdomStore>,
    /// keys currently being cold-planned — lets planning run *outside*
    /// the wisdom lock (submit() stays fast, unrelated keys plan
    /// concurrently) while still guaranteeing one planning event per key
    planning_inflight: Mutex<std::collections::BTreeSet<wisdom::WisdomKey>>,
    planning_cv: Condvar,
    stats: StatsCollector,
    /// one live model per engine — the single store profiling samples
    /// and served-batch timings both flow into. Lock rule: `models` and
    /// `wisdom` are never held at the same time (take one, release it,
    /// then take the other — see `predicted_cost` / `plan_for`).
    models: Mutex<BTreeMap<String, OnlineModel>>,
    /// injected machine-speed divisor for virtual backends (test/CI
    /// drift hook): execution time = simulator cost × factor
    virtual_slowdown: Mutex<BTreeMap<EngineId, f64>>,
    /// the simulator's *true* per-request cost per (engine, n) — fixed
    /// machine ground truth, independent of what the model believes
    virtual_base: Mutex<BTreeMap<(EngineId, usize), f64>>,
    /// the engine portfolio, when this service plans across engines.
    /// Lock order: `portfolio` may be taken before `models`/`wisdom`
    /// (seeding reads them), never the reverse.
    portfolio: Mutex<Option<PortfolioModel>>,
    /// virtual seconds consumed by virtual backends
    vclock: Mutex<f64>,
    next_id: std::sync::atomic::AtomicU64,
    shutdown: AtomicBool,
    started: Instant,
}

/// The concurrent 2D-DFT server.
pub struct Dft2dService {
    inner: Arc<Inner>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Builder: engine registry + wisdom seeding + paused start for
/// deterministic tests.
pub struct ServiceBuilder {
    cfg: ServiceConfig,
    engines: BTreeMap<EngineId, Backend>,
    wisdom: WisdomStore,
    portfolio_members: Option<Vec<EngineId>>,
    paused: bool,
}

impl ServiceBuilder {
    pub fn new(cfg: ServiceConfig) -> ServiceBuilder {
        ServiceBuilder {
            cfg,
            engines: BTreeMap::new(),
            wisdom: WisdomStore::new(),
            portfolio_members: None,
            paused: false,
        }
    }

    /// Register the from-scratch native engine under [`EngineId::Native`].
    pub fn native(self) -> ServiceBuilder {
        self.engine_typed(EngineId::Native, Arc::new(crate::coordinator::engine::NativeEngine))
    }

    /// Register a backend built by the engine registry — the
    /// consolidated construction path every CLI subcommand and the
    /// serve front end go through ([`EngineRegistry::build`]).
    pub fn engine_id(
        mut self,
        registry: &EngineRegistry,
        id: EngineId,
    ) -> Result<ServiceBuilder, String> {
        match registry.build(id)? {
            BuiltEngine::Real(e) => {
                self.engines.insert(id, Backend::Real(e));
            }
            BuiltEngine::Virtual(pkg) => {
                self.engines.insert(id, Backend::Virtual(pkg));
            }
        }
        Ok(self)
    }

    /// Register any real engine under a typed id.
    pub fn engine_typed(
        mut self,
        id: EngineId,
        engine: Arc<dyn RowFftEngine + Send + Sync>,
    ) -> ServiceBuilder {
        self.engines.insert(id, Backend::Real(engine));
        self
    }

    /// Register any real engine by name.
    ///
    /// Deprecated raw-string form kept for source compatibility: the
    /// name must parse as a canonical [`EngineId`] spelling (panics
    /// otherwise — builder misuse). Migrate to
    /// [`ServiceBuilder::engine_typed`] or
    /// [`ServiceBuilder::engine_id`].
    pub fn engine(self, name: &str, engine: Arc<dyn RowFftEngine + Send + Sync>) -> ServiceBuilder {
        let id = EngineId::parse(name)
            .unwrap_or_else(|| panic!("unknown engine name `{name}`; use engine_typed(EngineId)"));
        self.engine_typed(id, engine)
    }

    /// Register a virtual-time backend over a calibrated package model.
    pub fn virtual_id(mut self, package: Package) -> ServiceBuilder {
        self.engines.insert(EngineId::Sim(package), Backend::Virtual(package));
        self
    }

    /// Register a virtual-time backend by name.
    ///
    /// Deprecated raw-string form kept for source compatibility: the
    /// name must be the package's canonical `sim-*` spelling (panics on
    /// a mismatch). Migrate to [`ServiceBuilder::virtual_id`] /
    /// [`ServiceBuilder::engine_id`].
    pub fn virtual_package(self, name: &str, package: Package) -> ServiceBuilder {
        assert_eq!(
            EngineId::parse(name),
            Some(EngineId::Sim(package)),
            "virtual engine name `{name}` does not match package {}; use virtual_id",
            package.name()
        );
        self.virtual_id(package)
    }

    /// Enable portfolio planning over the given member engines:
    /// requests addressed to `"portfolio"` resolve to the fastest
    /// member per `(n, kind)` at admission. Persisted surfaces from the
    /// wisdom artifact (JSON v5) seed the model; members must also be
    /// registered as engines.
    pub fn portfolio(mut self, members: Vec<EngineId>) -> ServiceBuilder {
        self.portfolio_members = Some(members);
        self
    }

    /// Seed the wisdom store (e.g. loaded from disk).
    pub fn wisdom(mut self, store: WisdomStore) -> ServiceBuilder {
        self.wisdom = store;
        self
    }

    /// Load wisdom from a JSON file if it exists; missing files are a
    /// cold start, not an error.
    pub fn load_wisdom(mut self, path: &std::path::Path) -> Result<ServiceBuilder, String> {
        if path.exists() {
            self.wisdom = WisdomStore::load(path)?;
        }
        Ok(self)
    }

    /// Do not spawn workers yet — submissions queue up until
    /// [`Dft2dService::start`] (deterministic scheduling tests).
    pub fn paused(mut self) -> ServiceBuilder {
        self.paused = true;
        self
    }

    pub fn build(self) -> Dft2dService {
        for rec in self.wisdom.iter() {
            // virtual backends never execute a real FFT — warming the
            // native plan cache for their (paper-scale) sizes would cost
            // real memory and startup time for nothing
            if matches!(self.engines.get(&rec.engine), Some(Backend::Real(_))) {
                rec.warm_plan_cache();
            }
        }
        // measured row-tile widths persisted in the artifact (JSON v4)
        // seed the executor's calibration cache, so a restarted server
        // serves at the measured width without re-timing on its first
        // cold plan. `tile_width` applies the kernel-generation
        // staleness rule — widths timed against a retired row kernel
        // are skipped and the next cold plan re-calibrates.
        if self.engines.values().any(|b| matches!(b, Backend::Real(_))) {
            for t in self.wisdom.tiles() {
                if let Some(w) = self.wisdom.tile_width(t.n, t.kind) {
                    crate::dft::exec::set_measured_row_tile(t.n, w);
                }
            }
        }
        // one live model per engine: persisted deltas when the wisdom
        // file carried them, fresh otherwise; virtual backends get their
        // calibrated testbed as base, real engines get the latest
        // persisted measured surfaces (refreshed on every cold plan)
        let mut models: BTreeMap<String, OnlineModel> = BTreeMap::new();
        for (name, backend) in &self.engines {
            let mut model = self
                .wisdom
                .model(name.as_str())
                .cloned()
                .unwrap_or_else(|| OnlineModel::new(name.as_str(), self.cfg.drift));
            match backend {
                Backend::Virtual(pkg) => {
                    model.set_base(Arc::new(SimModel::paper_best(*pkg)));
                }
                Backend::Real(_) => {
                    // c2c stream ⇒ c2c surfaces only: an r2c record's
                    // ~2x-faster surfaces would halve every c2c cost
                    // estimate (wrong admission + SPJF weights)
                    if let Some(rec) = self.wisdom.iter().find(|r| {
                        r.engine == *name
                            && r.kind() == TransformKind::C2c
                            && !r.fpms.is_empty()
                    }) {
                        model.set_base(Arc::new(StaticModel::new(rec.fpms.clone())));
                    }
                }
            }
            models.insert(name.as_str().to_string(), model);
        }
        // resume persisted per-kind streams (keys like "native+r2c"):
        // the real plane's observations survive restarts exactly like
        // the c2c plane's, with its own measured surfaces as base
        for (name, m) in self.wisdom.models() {
            let Some((engine, _)) = name.split_once('+') else { continue };
            let Some(eid) = EngineId::parse(engine) else { continue };
            if models.contains_key(name) || !self.engines.contains_key(&eid) {
                continue;
            }
            let mut model = m.clone();
            if let Some(rec) = self.wisdom.iter().find(|r| {
                r.engine == eid && r.kind() == TransformKind::R2c && !r.fpms.is_empty()
            }) {
                model.set_base(Arc::new(StaticModel::new(rec.fpms.clone())));
            }
            models.insert(name.clone(), model);
        }
        // the portfolio: persisted surfaces/picks from the artifact when
        // the wisdom file carried them (JSON v5), reset to the builder's
        // member list; missing-from-wisdom is a cold start
        let persisted = self.wisdom.portfolio().cloned();
        let portfolio = self.portfolio_members.map(|members| {
            let mut pf = persisted.unwrap_or_default();
            pf.set_members(members);
            pf
        });
        let inner = Arc::new(Inner {
            cfg: self.cfg,
            engines: self.engines,
            queue: Mutex::new(BatchQueue::new()),
            cv: Condvar::new(),
            wisdom: Mutex::new(self.wisdom),
            planning_inflight: Mutex::new(std::collections::BTreeSet::new()),
            planning_cv: Condvar::new(),
            stats: StatsCollector::new(),
            models: Mutex::new(models),
            virtual_slowdown: Mutex::new(BTreeMap::new()),
            virtual_base: Mutex::new(BTreeMap::new()),
            portfolio: Mutex::new(portfolio),
            vclock: Mutex::new(0.0),
            next_id: std::sync::atomic::AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            started: Instant::now(),
        });
        let svc = Dft2dService { inner, workers: Mutex::new(Vec::new()) };
        if !self.paused {
            svc.start();
        }
        svc
    }
}

impl Dft2dService {
    /// Spawn the worker pool (idempotent).
    pub fn start(&self) {
        let mut workers = self.workers.lock().unwrap();
        if !workers.is_empty() {
            return;
        }
        for _ in 0..self.inner.cfg.workers.max(1) {
            let inner = Arc::clone(&self.inner);
            workers.push(std::thread::spawn(move || worker_loop(inner)));
        }
    }

    /// Submit a request: validation + FPM-informed admission, then the
    /// batching queue. Returns immediately with a blocking handle.
    pub fn submit(&self, req: Dft2dRequest) -> Result<ResponseHandle, ServiceError> {
        let (tx, rx) = mpsc::channel();
        let id = self.submit_inner(req, Completion::Channel(tx))?;
        Ok(ResponseHandle { id, rx })
    }

    /// Non-blocking submit with callback completion: the same validation
    /// and admission as [`Dft2dService::submit`], but the response is
    /// delivered by invoking `done` from the executing worker instead of
    /// through a channel. Exactly-once contract: an `Ok(id)` return
    /// guarantees `done` fires exactly once (with the response or an
    /// execution/shutdown error); a synchronous `Err` return guarantees
    /// it never fires — the caller still owns the failure.
    pub fn submit_with(
        &self,
        req: Dft2dRequest,
        done: Box<dyn FnOnce(Result<Dft2dResponse, ServiceError>) + Send>,
    ) -> Result<u64, ServiceError> {
        self.submit_inner(req, Completion::Callback(done))
    }

    fn submit_inner(&self, req: Dft2dRequest, tx: Completion) -> Result<u64, ServiceError> {
        if self.inner.shutdown.load(Ordering::Acquire) {
            return Err(ServiceError::ShuttingDown);
        }
        // typed engine identity: an unknown name becomes the stable
        // UnknownEngine rejection (code 1) here, before any other check
        let Some(requested) = EngineId::parse(&req.engine) else {
            return Err(ServiceError::UnknownEngine(req.engine));
        };
        // portfolio resolution happens BEFORE bucketing — batch buckets
        // key on the engine that executes, so "portfolio" must become a
        // concrete member id now (sticky per (n, kind) until drift)
        let engine = if requested == EngineId::Portfolio {
            match self.inner.resolve_portfolio(req.n, req.kind) {
                Some(member) => member,
                // this service has no portfolio configured
                None => return Err(ServiceError::UnknownEngine(req.engine)),
            }
        } else {
            requested
        };
        let Some(backend) = self.inner.engines.get(&engine) else {
            return Err(ServiceError::UnknownEngine(req.engine));
        };
        // real kinds run real kernels — virtual backends only price c2c
        if req.kind.is_real() && matches!(backend, Backend::Virtual(_)) {
            return Err(ServiceError::UnsupportedKind {
                engine: engine.to_string(),
                kind: req.kind.name(),
            });
        }
        // kind/direction coherence: r2c is forward-only, c2r inverse-only
        // (a mismatch is a kind problem, not a shape problem — diagnose
        // it as such instead of sending callers to debug dimensions)
        let dir_ok = match req.kind {
            TransformKind::C2c => true,
            TransformKind::R2c => req.direction == Direction::Forward,
            TransformKind::C2r => req.direction == Direction::Inverse,
        };
        if !dir_ok {
            return Err(ServiceError::UnsupportedKind {
                engine: engine.to_string(),
                kind: match req.kind {
                    TransformKind::R2c => "inverse r2c (r2c is forward-only)",
                    _ => "forward c2r (c2r is inverse-only)",
                },
            });
        }
        let is_probe = req.matrix.rows == 0 && req.matrix.cols == 0;
        let shape_ok = if is_probe {
            // empty-buffer probes only make sense in virtual time
            req.n > 0 && req.kind == TransformKind::C2c && matches!(backend, Backend::Virtual(_))
        } else if req.kind == TransformKind::C2r {
            // packed half-spectrum input: n rows × (n/2+1) columns
            req.n > 0 && req.matrix.rows == req.n && req.matrix.cols == half_cols(req.n)
        } else {
            req.matrix.rows == req.matrix.cols && req.matrix.rows == req.n && req.n > 0
        };
        if !shape_ok {
            return Err(ServiceError::BadShape {
                n: req.n,
                rows: req.matrix.rows,
                cols: req.matrix.cols,
                kind: req.kind.name(),
            });
        }
        let n = req.n;
        if !is_probe {
            // geometry said rows×cols; the buffers must agree — catching
            // this here turns a worker-side panic into a typed rejection
            let expected = req.matrix.rows * req.matrix.cols;
            if req.matrix.re.len() != expected || req.matrix.im.len() != expected {
                return Err(ServiceError::BadPayload {
                    n,
                    kind: req.kind.name(),
                    expected,
                    re_len: req.matrix.re.len(),
                    im_len: req.matrix.im.len(),
                });
            }
            if let Some(max_bytes) = self.inner.cfg.max_payload_bytes {
                let bytes =
                    (req.matrix.re.len() + req.matrix.im.len()) * std::mem::size_of::<f64>();
                if bytes > max_bytes {
                    self.inner.stats.record_rejection();
                    return Err(ServiceError::PayloadTooLarge {
                        n,
                        kind: req.kind.name(),
                        bytes,
                        max_bytes,
                    });
                }
            }
        }
        let cost = self.inner.predicted_cost(engine, n, req.kind);
        if let Some(hint) = req.deadline_hint {
            if cost > hint {
                self.inner.stats.record_rejection();
                return Err(ServiceError::DeadlineInfeasible {
                    n,
                    kind: req.kind.name(),
                    predicted_s: cost,
                    hint_s: hint,
                });
            }
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let pending = Pending { id, matrix: req.matrix, tx, submitted: Instant::now() };
        let key = BatchKey::new_kind(engine, n, req.direction, req.kind);
        {
            let mut q = self.inner.queue.lock().unwrap();
            // re-check under the queue lock: shutdown() flushes the queue
            // under this same lock, so a push after its flush would hang
            // the caller's wait() forever
            if self.inner.shutdown.load(Ordering::Acquire) {
                return Err(ServiceError::ShuttingDown);
            }
            q.push(key, cost, pending, self.inner.now_s());
            self.inner.stats.observe_queue_depth(q.len());
        }
        self.inner.cv.notify_one();
        Ok(id)
    }

    /// Model-predicted per-request seconds for `(engine, n, kind)` —
    /// live online model first, wisdom second, flat-speed fallback last.
    /// This is the estimate admission and SPJF schedule with; the
    /// [`crate::serve`] router prices shard placement through it.
    pub fn predicted_cost(&self, engine: &str, n: usize, kind: TransformKind) -> f64 {
        match EngineId::parse(engine) {
            Some(id) => self.inner.predicted_cost(id, n, kind),
            None => kind_flops(n, kind) / (DEFAULT_MFLOPS * 1e6),
        }
    }

    /// Queue-backlog snapshot: admitted-but-unexecuted requests and the
    /// sum of their model-predicted costs (the router / backpressure
    /// signal — predicted seconds until a fresh arrival reaches a worker,
    /// ignoring batching speedups).
    pub fn backlog(&self) -> Backlog {
        let q = self.inner.queue.lock().unwrap();
        Backlog { queued: q.len(), predicted_s: q.backlog_s() }
    }

    /// Lifetime drift-event count (cheap counter read — the serve router
    /// polls this to know when to re-score its placement cache).
    pub fn drift_events_total(&self) -> u64 {
        self.inner.stats.drift_events()
    }

    /// Counter snapshot over the service's lifetime so far.
    pub fn stats(&self) -> ServiceStats {
        self.inner.stats.snapshot(self.inner.now_s())
    }

    /// Start a stats phase window (serve-bench's cold/warm phases).
    pub fn stats_mark(&self) {
        self.inner.stats.mark(self.inner.now_s());
    }

    /// Stats over the window since the last [`Dft2dService::stats_mark`]
    /// (lifetime stats when never marked).
    pub fn stats_since_mark(&self) -> ServiceStats {
        self.inner.stats.since_mark(self.inner.now_s())
    }

    /// Clone of the current wisdom store, including the live models'
    /// deltas + drift logs (what [`Dft2dService::save_wisdom`] writes).
    pub fn wisdom_snapshot(&self) -> WisdomStore {
        let mut store = self.inner.wisdom.lock().unwrap().clone();
        for (engine, model) in self.inner.models.lock().unwrap().iter() {
            if model.observations() > 0 || !model.drift_events().is_empty() {
                store.set_model(engine, model.clone());
            }
        }
        if let Some(pf) = self.inner.portfolio.lock().unwrap().as_ref() {
            if !pf.is_empty() {
                store.set_portfolio(pf.clone());
            }
        }
        store
    }

    /// Persist the current wisdom store + model deltas + drift log.
    pub fn save_wisdom(&self, path: &std::path::Path) -> Result<(), String> {
        self.wisdom_snapshot().save(path)
    }

    /// Snapshot of an engine's live online model.
    pub fn model_snapshot(&self, engine: &str) -> Option<OnlineModel> {
        self.inner.models.lock().unwrap().get(engine).cloned()
    }

    /// The portfolio's sticky picks — `(n, kind, engine)` incumbents in
    /// `(n, kind)` order. Empty when this service has no portfolio.
    pub fn portfolio_picks(&self) -> Vec<(usize, TransformKind, EngineId)> {
        self.inner.portfolio.lock().unwrap().as_ref().map(|p| p.picks()).unwrap_or_default()
    }

    /// The portfolio's re-pick log: actual engine switches after drift
    /// evicted an incumbent, in chronological order. Empty when this
    /// service has no portfolio (or nothing ever switched).
    pub fn portfolio_repicks(&self) -> Vec<RepickEvent> {
        self.inner
            .portfolio
            .lock()
            .unwrap()
            .as_ref()
            .map(|p| p.repick_log().to_vec())
            .unwrap_or_default()
    }

    /// Inject a machine-speed shift on a virtual backend: execution
    /// takes `factor`× the simulator's predicted time from now on. This
    /// is the deterministic drift hook for tests and the CI smoke — the
    /// model only ever sees the resulting timings, never the factor.
    pub fn set_virtual_slowdown(&self, engine: &str, factor: f64) {
        assert!(factor.is_finite() && factor > 0.0, "slowdown factor must be positive");
        let id = EngineId::parse(engine)
            .unwrap_or_else(|| panic!("set_virtual_slowdown: unknown engine `{engine}`"));
        self.inner.virtual_slowdown.lock().unwrap().insert(id, factor);
    }

    /// The memoized plan for `(engine, n)` under the service's group
    /// count, if planning has happened.
    pub fn planned(&self, engine: &str, n: usize) -> Option<PlannedTransform> {
        self.planned_kind(engine, n, TransformKind::C2c)
    }

    /// [`Dft2dService::planned`] for an explicit transform kind.
    pub fn planned_kind(
        &self,
        engine: &str,
        n: usize,
        kind: TransformKind,
    ) -> Option<PlannedTransform> {
        let id = EngineId::parse(engine)?;
        let p = self.inner.plan_groups(id);
        self.inner.wisdom.lock().unwrap().get_kind(id, n, p, kind).map(|r| r.plan.clone())
    }

    /// Current virtual clock (virtual backends only; 0 otherwise).
    pub fn virtual_now_s(&self) -> f64 {
        *self.inner.vclock.lock().unwrap()
    }

    /// Graceful stop: reject new submissions, let the workers drain and
    /// answer everything already queued, then join the pool. Requests
    /// that no worker will ever pick up (a paused service that was never
    /// started) receive [`ServiceError::ShuttingDown`] instead.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::Release);
        {
            // notify under the queue lock: a worker between a failed pop
            // and cv.wait holds the lock, so this cannot race past it
            let _q = self.inner.queue.lock().unwrap();
            self.inner.cv.notify_all();
        }
        let mut workers = self.workers.lock().unwrap();
        for w in workers.drain(..) {
            let _ = w.join();
        }
        // flush anything the workers didn't pick up
        let mut q = self.inner.queue.lock().unwrap();
        while let Some(b) = q.pop(self.inner.now_s(), 0.0, usize::MAX) {
            for (p, _) in b.entries {
                p.tx.send(Err(ServiceError::ShuttingDown));
            }
        }
    }
}

impl Drop for Dft2dService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Inner {
    fn now_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// The group count planning uses for an engine (virtual backends pin
    /// the paper-best p of their package).
    fn plan_groups(&self, engine: EngineId) -> usize {
        match self.engines.get(&engine) {
            Some(Backend::Virtual(pkg)) => pkg.best_groups().p,
            _ => self.cfg.planning.groups,
        }
    }

    /// Cost estimate for one request, best source first: the live
    /// model's refined estimate (what the machine actually did
    /// recently), then the wisdom record's planned prediction, then the
    /// conservative flat-speed fallback. SPJF weights and admission
    /// both come through here — scheduling follows the machine. Each
    /// `(engine, kind)` plane has its own model stream and wisdom key:
    /// real requests do ~half the work, so sharing an estimate with c2c
    /// would starve one kind or admit the other into missed deadlines.
    fn predicted_cost(&self, engine: EngineId, n: usize, kind: TransformKind) -> f64 {
        if engine == EngineId::Portfolio {
            // price the member the portfolio would run — resolution is
            // sticky, so this is the engine submit() will actually pick
            if let Some(member) = self.resolve_portfolio(n, kind) {
                return self.predicted_cost(member, n, kind);
            }
            return kind_flops(n, kind) / (DEFAULT_MFLOPS * 1e6);
        }
        let (x, y) = observation_point(n);
        if let Some(model) = self.models.lock().unwrap().get(&model_key(engine.as_str(), kind)) {
            if let Some(t) = model.refined_time(x, y) {
                return t;
            }
        }
        let p = self.plan_groups(engine);
        if let Some(rec) = self.wisdom.lock().unwrap().get_kind(engine, n, p, kind) {
            return rec.predicted_cost_s;
        }
        kind_flops(n, kind) / (DEFAULT_MFLOPS * 1e6)
    }

    /// Portfolio resolution for one `(n, kind)` point: seed any missing
    /// member surface from the best cold source, then ask the portfolio
    /// for the sticky winner. `None` when this service has no portfolio.
    /// (Lock order: `portfolio` before `models`/`wisdom` — see the
    /// field's doc.)
    fn resolve_portfolio(&self, n: usize, kind: TransformKind) -> Option<EngineId> {
        let kind = kind.plan_kind();
        let mut guard = self.portfolio.lock().unwrap();
        let pf = guard.as_mut()?;
        for m in pf.members().to_vec() {
            if pf.surface(m, n, kind).is_none() {
                if let Some(t) = self.member_cost_cold(m, n, kind) {
                    pf.set_surface(m, n, kind, t);
                }
            }
        }
        pf.best_engine(n, kind, self.cfg.planning.groups)
    }

    /// A member's cold cost at `(n, kind)`: the live model's refined
    /// estimate first (it tracks the machine), the wisdom record's
    /// planned prediction second, the calibrated simulator belief for
    /// virtual members last. `None` when nothing is known — the
    /// portfolio then falls back to its first member.
    fn member_cost_cold(&self, engine: EngineId, n: usize, kind: TransformKind) -> Option<f64> {
        let (x, y) = observation_point(n);
        if let Some(m) = self.models.lock().unwrap().get(&model_key(engine.as_str(), kind)) {
            if let Some(t) = m.refined_time(x, y) {
                return Some(t);
            }
        }
        let p = self.plan_groups(engine);
        if let Some(rec) = self.wisdom.lock().unwrap().get_kind(engine, n, p, kind) {
            return Some(rec.predicted_cost_s);
        }
        if let Some(Backend::Virtual(pkg)) = self.engines.get(&engine) {
            let point = crate::simulator::vexec::predict_point(*pkg, n);
            return Some(if self.cfg.planning.pad_cost.is_some() {
                point.t_pad
            } else {
                point.t_fpm
            });
        }
        None
    }

    /// The simulator's fixed ground-truth per-request cost for a
    /// virtual (engine, n) — memoized once, never affected by what the
    /// model currently believes (re-planning must not move the machine).
    fn virtual_true_cost(&self, engine: EngineId, pkg: Package, n: usize) -> f64 {
        let mut base = self.virtual_base.lock().unwrap();
        *base.entry((engine, n)).or_insert_with(|| {
            let point = crate::simulator::vexec::predict_point(pkg, n);
            if self.cfg.planning.pad_cost.is_some() {
                point.t_pad
            } else {
                point.t_fpm
            }
        })
    }

    /// Wisdom lookup-or-plan. Returns the record plus whether this call
    /// paid the cold planning cost.
    ///
    /// The expensive measurement runs *outside* the wisdom lock (so
    /// `submit()`'s cost lookups never stall behind a 1.5s FPM build and
    /// unrelated keys plan concurrently); a per-key in-flight set keeps
    /// the cold-plan counter exact — one planning event per key, ever.
    fn plan_for(&self, key: &BatchKey) -> (WisdomRecord, bool) {
        let backend = self.engines.get(&key.engine).expect("validated at submit");
        let p = self.plan_groups(key.engine);
        let kind = key.kind.plan_kind();
        let wkey: wisdom::WisdomKey = (key.engine, key.n, p, kind);

        // claim the key, or wait for whoever holds it (lock order:
        // planning_inflight, then wisdom — never the reverse)
        {
            let mut inflight = self.planning_inflight.lock().unwrap();
            loop {
                if let Some(rec) = self.wisdom.lock().unwrap().get_kind(key.engine, key.n, p, kind)
                {
                    self.stats.record_wisdom_hit();
                    return (rec.clone(), false);
                }
                if !inflight.contains(&wkey) {
                    inflight.insert(wkey);
                    break;
                }
                inflight = self.planning_cv.wait(inflight).unwrap();
            }
        }

        // we own the cold plan for this key; no locks held while measuring
        self.stats.record_planning_event();
        let mkey = model_key(key.engine.as_str(), kind);
        let mut tile_widths: Vec<(usize, usize)> = Vec::new();
        let rec = match backend {
            Backend::Real(engine) => {
                let (rec, samples) = WisdomRecord::from_measurement_sampled(
                    key.engine,
                    engine.as_ref(),
                    key.n,
                    &self.cfg.planning,
                    kind,
                );
                rec.warm_plan_cache();
                // one-shot row-tile calibration for every row length the
                // plan can execute (pads included): the cold-plan path
                // *is* the executor's warmup, so steady state serves at
                // the measured width and never pays the timing again
                let mut tile_lens = rec.plan.pad_lens();
                tile_lens.push(key.n);
                tile_lens.sort_unstable();
                tile_lens.dedup();
                for len in tile_lens.into_iter().filter(|&l| l > 0) {
                    tile_widths.push((len, crate::dft::exec::calibrate_row_tile(len)));
                }
                // profiling emits into the same model store the serving
                // executor appends to, and refreshes the static base.
                // A profiler sample is *per group* (x rows on one of p
                // concurrent groups), so it lands at the platform row
                // count p·x; the whole-request point (2y, y) is owned by
                // the serving executor — a one-phase profiling time there
                // would contaminate the live whole-request estimate, so
                // it is skipped. Each kind's samples feed that kind's
                // own stream (real planes are ~2x faster).
                {
                    let mut models = self.models.lock().unwrap();
                    let model = models
                        .entry(mkey.clone())
                        .or_insert_with(|| OnlineModel::new(&mkey, self.cfg.drift));
                    for (x, y, t) in samples {
                        let platform_x = rec.p * x;
                        if (platform_x, y) == observation_point(y) {
                            continue;
                        }
                        model.observe(platform_x, y, t);
                    }
                    if !rec.fpms.is_empty() {
                        model.set_base(Arc::new(StaticModel::new(rec.fpms.clone())));
                    }
                }
                rec
            }
            // virtual records never execute real FFTs — no cache warmup.
            // Once the live model has refined data (post-drift replan),
            // planning runs against its refreshed sections instead of
            // the pristine simulator surfaces.
            Backend::Virtual(pkg) => {
                let cfg = pkg.best_groups();
                let model_rec = {
                    let models = self.models.lock().unwrap();
                    models.get(key.engine.as_str()).filter(|m| m.has_refined()).map(|m| {
                        WisdomRecord::from_model(
                            key.engine,
                            m,
                            key.n,
                            cfg.p,
                            cfg.t,
                            crate::simulator::vexec::EPS_IDENTICAL,
                            self.cfg.planning.pad_cost,
                            crate::simulator::vexec::PAD_WINDOW,
                        )
                    })
                };
                model_rec.unwrap_or_else(|| {
                    WisdomRecord::from_simulator(*pkg, key.n, self.cfg.planning.pad_cost.is_some())
                })
            }
        };
        {
            let mut w = self.wisdom.lock().unwrap();
            w.insert(rec.clone());
            // the calibration winners ride the same artifact (v4 tiles)
            for (len, width) in tile_widths {
                w.set_tile(len, kind, width);
            }
        }
        let mut inflight = self.planning_inflight.lock().unwrap();
        inflight.remove(&wkey);
        self.planning_cv.notify_all();
        (rec, true)
    }

    fn execute_batch(&self, batch: sched::Batch<Pending>) {
        let key = batch.key;
        let (rec, planned_cold) = self.plan_for(&key);
        let size = batch.entries.len();
        self.stats.record_batch(size);
        // what the scheduler believed this batch costs per request —
        // compared against the measured time below (calibration)
        let predicted_s = self.predicted_cost(key.engine, key.n, key.kind);

        let mut items: Vec<Pending> = Vec::with_capacity(size);
        let mut waits: Vec<f64> = Vec::with_capacity(size);
        let enqueue_now = self.now_s();
        for (p, enq_s) in batch.entries {
            waits.push((enqueue_now - enq_s).max(0.0));
            items.push(p);
        }

        let backend = self.engines.get(&key.engine).expect("validated at submit").clone();
        let mut virtual_done: Option<f64> = None;
        let mut executed_batch_s = 0.0;
        // per-phase timings of the forward pipeline (row stage vs the
        // memory-bound column stage) — the drift classifier's signal
        let mut phase_timings: Option<PhaseTimings> = None;
        let exec_result: Result<(), ServiceError> = match &backend {
            Backend::Real(engine) => {
                let t0 = Instant::now();
                let r = match key.kind {
                    TransformKind::R2c => {
                        // real forward: the batched stage-DAG real
                        // executor writes packed half spectra into fresh
                        // output matrices (the transform is out-of-place
                        // by nature — input is real, output complex)
                        let n = key.n;
                        let nc = half_cols(n);
                        let mut outs: Vec<SignalMatrix> =
                            (0..size).map(|_| SignalMatrix::zeros(n, nc)).collect();
                        let r = {
                            let srcs: Vec<&[f64]> =
                                items.iter().map(|p| p.matrix.re.as_slice()).collect();
                            let mut dst_refs: Vec<&mut SignalMatrix> = outs.iter_mut().collect();
                            execute_real_batch_with_mode(
                                engine.as_ref(),
                                &rec.plan,
                                &srcs,
                                &mut dst_refs,
                                rec.t,
                                self.cfg.pipeline,
                            )
                        };
                        match r {
                            Ok(timings) => {
                                phase_timings = Some(timings);
                                for (p, out) in items.iter_mut().zip(outs) {
                                    p.matrix = out;
                                }
                                Ok(())
                            }
                            Err(e) => Err(ServiceError::Engine(e.with_kind(key.kind).to_string())),
                        }
                    }
                    TransformKind::C2r => {
                        // real inverse: exact irfft2d path (like c2c
                        // inverse, padding is forward-only); the owned
                        // variant runs the column phase in place on the
                        // request's own spectrum — no clone
                        let threads = rec.p * rec.t;
                        for p in items.iter_mut() {
                            let packed =
                                std::mem::replace(&mut p.matrix, SignalMatrix::zeros(0, 0));
                            let real =
                                irfft2d_owned_with_mode(packed, threads, self.cfg.pipeline);
                            let len = real.data.len();
                            p.matrix = SignalMatrix {
                                rows: real.rows,
                                cols: real.cols,
                                re: real.data,
                                im: vec![0.0; len],
                            };
                        }
                        Ok(())
                    }
                    TransformKind::C2c if key.forward => {
                        let mut mats: Vec<&mut SignalMatrix> =
                            items.iter_mut().map(|p| &mut p.matrix).collect();
                        match batch::execute_planned_batch_with_mode(
                            engine.as_ref(),
                            &rec.plan,
                            &mut mats,
                            rec.t,
                            self.cfg.transpose_block,
                            self.cfg.pipeline,
                        ) {
                            Ok(timings) => {
                                phase_timings = Some(timings);
                                Ok(())
                            }
                            Err(e) => Err(ServiceError::Engine(e.with_kind(key.kind).to_string())),
                        }
                    }
                    TransformKind::C2c => {
                        // inverse: exact dft2d path (padding is forward-only
                        // spectral interpolation — see coordinator::pad docs)
                        let threads = rec.p * rec.t;
                        for p in items.iter_mut() {
                            crate::dft::dft2d::dft2d_with_mode(
                                &mut p.matrix,
                                Direction::Inverse,
                                threads,
                                self.cfg.pipeline,
                            );
                        }
                        Ok(())
                    }
                };
                executed_batch_s = t0.elapsed().as_secs_f64();
                r
            }
            Backend::Virtual(pkg) => {
                // virtual time: the machine's ground-truth cost for
                // `size` stacked requests, times any injected slowdown;
                // matrices pass through untouched
                let true_cost = self.virtual_true_cost(key.engine, *pkg, key.n);
                let factor = self
                    .virtual_slowdown
                    .lock()
                    .unwrap()
                    .get(&key.engine)
                    .copied()
                    .unwrap_or(1.0);
                executed_batch_s = true_cost * factor * size as f64;
                let mut clock = self.vclock.lock().unwrap();
                *clock += executed_batch_s;
                virtual_done = Some(*clock);
                Ok(())
            }
        };

        let executed_s = executed_batch_s / size.max(1) as f64;
        // a fired drift event carries its classification (compute vs
        // memory-bandwidth, from the per-phase streams) — the reaction
        // below is class-dependent, so keep the whole event's class
        let mut drifted: Option<DriftClass> = None;
        if exec_result.is_ok() && key.forward {
            // the feedback loop: fold the measured per-request time into
            // the live model and record calibration (cheap, lock-scoped);
            // the expensive drift *reaction* is deferred until after the
            // responses are delivered
            if predicted_s > 0.0 && executed_s > 0.0 {
                self.stats.record_calibration((predicted_s - executed_s).abs() / executed_s);
            }
            let (x, y) = observation_point(key.n);
            drifted = {
                let mut models = self.models.lock().unwrap();
                let mkey = model_key(key.engine.as_str(), key.kind);
                let m = models
                    .entry(mkey.clone())
                    .or_insert_with(|| OnlineModel::new(&mkey, self.cfg.drift));
                // phase streams first: a whole-point drift event
                // classifies itself from them (compute vs
                // memory-bandwidth) at the moment it fires
                if let Some(ph) = phase_timings {
                    let b = size.max(1) as f64;
                    m.observe_phase(Phase::Row, x, y, ph.row_s / b);
                    m.observe_phase(Phase::Col, x, y, ph.col_s / b);
                }
                m.observe(x, y, executed_s).map(|e| e.class)
            };
            // the portfolio learns from the same measurement: refine the
            // executing member's surface; on drift, degrade the whole
            // engine by the observed slowdown and evict its picks so the
            // next request at those points re-picks
            if let Some(pf) = self.portfolio.lock().unwrap().as_mut() {
                pf.observe_cost(key.engine, key.n, key.kind.plan_kind(), executed_s);
                if drifted.is_some() {
                    if predicted_s > 0.0 && executed_s > predicted_s {
                        pf.scale_engine(key.engine, (executed_s / predicted_s).min(100.0));
                    }
                    pf.note_drift(key.engine);
                }
            }
        }

        let flops = kind_flops(key.n, key.kind);
        for (p, wait) in items.into_iter().zip(waits) {
            match &exec_result {
                Ok(()) => {
                    let latency = p.submitted.elapsed().as_secs_f64();
                    self.stats.record_completion(latency, wait, flops);
                    let resp = Dft2dResponse {
                        id: p.id,
                        matrix: p.matrix,
                        report: ResponseReport {
                            engine: key.engine,
                            d: rec.plan.d.clone(),
                            pads: rec.plan.pad_lens(),
                            algorithm: rec.plan.algorithm.name().to_string(),
                            batched_with: size,
                            planned_cold,
                            queue_wait_s: wait,
                            latency_s: latency,
                            predicted_s,
                            executed_s,
                            virtual_done_s: virtual_done,
                        },
                    };
                    p.tx.send(Ok(resp));
                }
                Err(e) => {
                    self.stats.record_failure();
                    p.tx.send(Err(e.clone()));
                }
            }
        }

        if let Some(class) = drifted {
            // responses are out; now invalidate the affected wisdom
            // partition and re-plan in the worker, background wrt the
            // clients of this batch
            self.drift_replan(&key, &rec, class);
        }
    }

    /// Drift reaction: drop the stale wisdom record and re-plan against
    /// the refreshed sections. Real engines whose invalidated record
    /// carries its measured surfaces re-plan from those surfaces
    /// rescaled by the model's observed speed ratio — POPTA/HPOPTA +
    /// pad selection re-run with *no re-measurement*; otherwise (and
    /// for virtual backends, via `plan_for`'s model path) the normal
    /// cold-plan route runs.
    ///
    /// **Memory-classified** drift additionally drops the measured
    /// row-tile widths for this key's row lengths — both from the
    /// executor's live cache and from the wisdom artifact. A tile width
    /// is a pure cache-behaviour artifact (it times L1/L2 pressure of
    /// tiled rows), so a memory-regime shift is precisely the event
    /// that invalidates it; compute drift leaves the widths alone (the
    /// kernel's relative width ranking is not what moved).
    fn drift_replan(&self, key: &BatchKey, old: &WisdomRecord, class: DriftClass) {
        self.stats.record_drift();
        let p = self.plan_groups(key.engine);
        let kind = key.kind.plan_kind();
        {
            let mut w = self.wisdom.lock().unwrap();
            w.remove(key.engine, key.n, p, kind);
            if class == DriftClass::Memory {
                let mut lens = old.plan.pad_lens();
                lens.push(key.n);
                lens.sort_unstable();
                lens.dedup();
                for len in lens.into_iter().filter(|&l| l > 0) {
                    crate::dft::exec::clear_measured_row_tile(len);
                    w.clear_tile(len, kind);
                }
            }
        }
        let is_real_backend = matches!(self.engines.get(&key.engine), Some(Backend::Real(_)));
        if is_real_backend && !old.fpms.is_empty() {
            let model = {
                let mut models = self.models.lock().unwrap();
                models.get_mut(&model_key(key.engine.as_str(), kind)).map(|m| {
                    // the invalidated record's surfaces are this key's
                    // own y = N sections — the right base to rescale
                    m.set_base(Arc::new(StaticModel::new(old.fpms.clone())));
                    m.clone()
                })
            };
            if let Some(model) = model {
                self.stats.record_planning_event();
                let rec = WisdomRecord::from_model_kind(
                    key.engine,
                    &model,
                    key.n,
                    old.p,
                    old.t,
                    old.eps,
                    self.cfg.planning.pad_cost,
                    wisdom::PAD_SEARCH_WINDOW,
                    kind,
                );
                rec.warm_plan_cache();
                self.wisdom.lock().unwrap().insert(rec);
                return;
            }
        }
        let _ = self.plan_for(key);
    }
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let batch = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if let Some(b) = q.pop(
                    inner.now_s(),
                    inner.cfg.starvation_bound_s,
                    inner.cfg.max_batch,
                ) {
                    break Some(b);
                }
                if inner.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = inner.cv.wait(q).unwrap();
            }
        };
        match batch {
            Some(b) => inner.execute_batch(b),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ServiceConfig {
        ServiceConfig {
            workers: 2,
            max_batch: 4,
            planning: PlanningConfig {
                groups: 2,
                threads_per_group: 1,
                rep_scale: 10_000,
                ..PlanningConfig::default()
            },
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn submit_validates_inputs() {
        let svc = ServiceBuilder::new(quick_cfg()).native().build();
        let bad = Dft2dRequest::forward("native", SignalMatrix::random(4, 6, 1));
        assert!(matches!(svc.submit(bad), Err(ServiceError::BadShape { .. })));
        let nope = Dft2dRequest::forward("cufft", SignalMatrix::random(4, 4, 1));
        assert!(matches!(svc.submit(nope), Err(ServiceError::UnknownEngine(_))));
        svc.shutdown();
        let late = Dft2dRequest::forward("native", SignalMatrix::random(4, 4, 1));
        assert_eq!(svc.submit(late).unwrap_err(), ServiceError::ShuttingDown);
    }

    #[test]
    fn forward_then_inverse_roundtrips() {
        let svc = ServiceBuilder::new(quick_cfg()).native().build();
        let orig = SignalMatrix::random(16, 16, 9);
        let fwd = svc
            .submit(Dft2dRequest::forward("native", orig.clone()))
            .unwrap()
            .wait()
            .unwrap();
        let back = svc
            .submit(Dft2dRequest::inverse("native", fwd.matrix))
            .unwrap()
            .wait()
            .unwrap();
        let err = back.matrix.max_abs_diff(&orig) / orig.norm().max(1.0);
        assert!(err < 1e-10, "roundtrip rel err {err}");
        assert_eq!(fwd.report.d.iter().sum::<usize>(), 16);
        svc.shutdown();
    }

    #[test]
    fn virtual_backend_prices_without_touching_data() {
        let svc = ServiceBuilder::new(quick_cfg())
            .virtual_package("sim-mkl", Package::Mkl)
            .build();
        let orig = SignalMatrix::random(8, 8, 3);
        let resp = svc
            .submit(Dft2dRequest::forward("sim-mkl", orig.clone()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp.matrix, orig, "virtual path must not transform data");
        assert!(resp.report.virtual_done_s.unwrap() > 0.0);
        assert!(svc.virtual_now_s() > 0.0);
        svc.shutdown();
    }

    #[test]
    fn deadline_admission_uses_wisdom() {
        let mut store = WisdomStore::new();
        store.insert(WisdomRecord::from_simulator(Package::Mkl, 24_704, false));
        let svc = ServiceBuilder::new(quick_cfg())
            .virtual_package("sim-mkl", Package::Mkl)
            .wisdom(store)
            .paused()
            .build();
        let predicted = svc.predicted_cost("sim-mkl", 24_704, TransformKind::C2c);
        assert!(predicted > 0.0, "wisdom-backed prediction must exist");
        // a deadline below the FPM-predicted cost is rejected at submit
        let req = Dft2dRequest::probe("sim-mkl", 24_704).with_deadline(predicted / 2.0);
        let err = svc.submit(req).unwrap_err();
        assert!(matches!(err, ServiceError::DeadlineInfeasible { .. }), "{err}");
        assert_eq!(svc.stats().rejected, 1);
        // a feasible deadline is admitted
        let ok = Dft2dRequest::probe("sim-mkl", 24_704).with_deadline(predicted * 2.0);
        let h = svc.submit(ok).unwrap();
        svc.start();
        let resp = h.wait().unwrap();
        assert_eq!(resp.report.batched_with, 1);
        svc.shutdown();
    }

    #[test]
    fn real_forward_then_inverse_roundtrips() {
        let svc = ServiceBuilder::new(quick_cfg()).native().build();
        let orig = SignalMatrix::random_real(16, 16, 21);
        let fwd = svc
            .submit(Dft2dRequest::real_forward("native", orig.clone()))
            .unwrap()
            .wait()
            .unwrap();
        // the response is the Hermitian-packed half spectrum
        assert_eq!((fwd.matrix.rows, fwd.matrix.cols), (16, half_cols(16)));
        let back = svc
            .submit(Dft2dRequest::real_inverse("native", 16, fwd.matrix))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!((back.matrix.rows, back.matrix.cols), (16, 16));
        let err = back.matrix.max_abs_diff(&orig) / orig.norm().max(1.0);
        assert!(err < 1e-10, "real roundtrip rel err {err}");
        // the real plane planned its own kind-keyed wisdom record
        assert_eq!(
            svc.planned_kind("native", 16, TransformKind::R2c).unwrap().kind,
            TransformKind::R2c
        );
        svc.shutdown();
    }

    #[test]
    fn real_kind_validation() {
        let svc = ServiceBuilder::new(quick_cfg())
            .native()
            .virtual_package("sim-mkl", Package::Mkl)
            .build();
        // real kinds never run on virtual backends (nothing to pack)
        let err = svc
            .submit(Dft2dRequest::real_forward("sim-mkl", SignalMatrix::random_real(8, 8, 1)))
            .unwrap_err();
        assert!(matches!(err, ServiceError::UnsupportedKind { .. }), "{err}");
        // c2r input must be the packed n×(n/2+1) rectangle
        let err = svc
            .submit(Dft2dRequest::real_inverse("native", 8, SignalMatrix::zeros(8, 8)))
            .unwrap_err();
        assert!(matches!(err, ServiceError::BadShape { .. }), "{err}");
        // per-kind model keys: c2c stream is the bare engine name
        assert_eq!(model_key("native", TransformKind::C2c), "native");
        assert_eq!(model_key("native", TransformKind::R2c), "native+r2c");
        assert_eq!(model_key("native", TransformKind::C2r), "native+r2c");
        svc.shutdown();
    }

    #[test]
    fn probe_requires_virtual_backend() {
        let svc = ServiceBuilder::new(quick_cfg()).native().build();
        let err = svc.submit(Dft2dRequest::probe("native", 1024)).unwrap_err();
        assert!(matches!(err, ServiceError::BadShape { .. }));
        svc.shutdown();
    }

    #[test]
    fn cold_plans_calibrate_and_persist_tile_widths() {
        // n=18 is unique to this test: the measured-tile cache is
        // process-global, so a shared n would race other service tests
        // (harmlessly for correctness — widths never change bits — but
        // this test asserts on exact cache contents)
        let n = 18;
        let svc = ServiceBuilder::new(quick_cfg()).native().build();
        let resp = svc
            .submit(Dft2dRequest::forward("native", SignalMatrix::random(n, n, 5)))
            .unwrap()
            .wait()
            .unwrap();
        assert!(resp.report.planned_cold);
        // the cold plan ran the one-shot width calibration...
        let w = crate::dft::exec::measured_row_tile(n).expect("cold plan calibrates");
        assert!(crate::dft::exec::ROW_TILE_MEASURE_CANDIDATES.contains(&w));
        // ...and the winner rides the wisdom artifact (JSON v4 tiles)
        let snap = svc.wisdom_snapshot();
        assert_eq!(snap.tile_width(n, TransformKind::C2c), Some(w));
        svc.shutdown();
        // a rebuilt service seeds the executor cache from the artifact
        // (no re-timing on restart)
        crate::dft::exec::clear_measured_row_tile(n);
        assert_eq!(crate::dft::exec::measured_row_tile(n), None);
        let svc2 = ServiceBuilder::new(quick_cfg()).native().wisdom(snap).paused().build();
        assert_eq!(crate::dft::exec::measured_row_tile(n), Some(w));
        svc2.shutdown();
    }

    #[test]
    fn portfolio_resolves_before_bucketing() {
        // two sim members: the service must resolve "portfolio" to a
        // concrete member at admission, bucket and execute there, and
        // report that engine back
        let svc = ServiceBuilder::new(quick_cfg())
            .virtual_id(Package::Fftw3)
            .virtual_id(Package::Mkl)
            .portfolio(vec![EngineId::Sim(Package::Fftw3), EngineId::Sim(Package::Mkl)])
            .build();
        let resp = svc.submit(Dft2dRequest::probe("portfolio", 24_704)).unwrap().wait().unwrap();
        assert!(matches!(resp.report.engine, EngineId::Sim(_)), "{}", resp.report.engine);
        // the pick is cached and names the member that executed
        let picks = svc.portfolio_picks();
        assert_eq!(picks.len(), 1);
        assert_eq!((picks[0].0, picks[0].2), (24_704, resp.report.engine));
        // the resolved member rides the wisdom snapshot (v5 portfolio)
        let snap = svc.wisdom_snapshot();
        assert_eq!(
            snap.portfolio().unwrap().pick(24_704, TransformKind::C2c),
            Some(resp.report.engine)
        );
        svc.shutdown();
    }

    #[test]
    fn portfolio_without_config_is_unknown_engine() {
        let svc = ServiceBuilder::new(quick_cfg()).native().build();
        let err = svc
            .submit(Dft2dRequest::forward("portfolio", SignalMatrix::random(8, 8, 1)))
            .unwrap_err();
        assert!(matches!(err, ServiceError::UnknownEngine(_)), "{err}");
        svc.shutdown();
    }

    #[test]
    fn portfolio_over_native_is_bit_identical_to_direct() {
        let m = SignalMatrix::random(16, 16, 7);
        let direct = {
            let svc = ServiceBuilder::new(quick_cfg()).native().build();
            let r =
                svc.submit(Dft2dRequest::forward("native", m.clone())).unwrap().wait().unwrap();
            svc.shutdown();
            r.matrix
        };
        let svc =
            ServiceBuilder::new(quick_cfg()).native().portfolio(vec![EngineId::Native]).build();
        let r = svc.submit(Dft2dRequest::forward("portfolio", m)).unwrap().wait().unwrap();
        assert_eq!(r.report.engine, EngineId::Native);
        assert_eq!(r.matrix, direct, "portfolio must not change a single output bit");
        svc.shutdown();
    }

    #[test]
    fn stats_count_batches_and_planning() {
        let svc = ServiceBuilder::new(quick_cfg()).native().paused().build();
        let handles: Vec<ResponseHandle> = (0..4)
            .map(|s| {
                svc.submit(Dft2dRequest::forward("native", SignalMatrix::random(16, 16, s)))
                    .unwrap()
            })
            .collect();
        svc.start();
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.report.batched_with, 4, "paused submits must coalesce");
        }
        let s = svc.stats();
        assert_eq!(s.completed, 4);
        assert_eq!(s.planning_events, 1, "one cold plan for the shared key");
        assert_eq!(s.batches, 1);
        assert_eq!(s.max_batch, 4);
        assert!(s.peak_queue_depth >= 4);
        svc.shutdown();
    }
}
