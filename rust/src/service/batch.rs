//! Coalesced execution of a planned transform over a batch of matrices.
//!
//! Under the default [`PipelineMode::Fused`], the batch runs the plan's
//! compiled [`crate::coordinator::plan::ExecPipeline`] as **one stage
//! DAG across all B matrices**: tile tasks flow through the pool with
//! no per-phase
//! barrier, so matrix b's column tiles execute while matrix b+1's row
//! tiles are still in flight, column FFTs run directly on row-major
//! storage (per-tile transpose into pooled per-thread scratch — the
//! global transpose passes are gone), and a padded plan's pad length is
//! a tile *stride*, not a gather-matrix copy: the padded-batch copy
//! cost drops from 4 whole-matrix passes (gather + scatter per phase)
//! to the 2 that double as the column-phase transpose.
//!
//! Under [`PipelineMode::Barrier`] the pre-pipeline behaviour remains:
//! B same-size requests share the four-step skeleton, each abstract
//! processor runs **one** row-FFT call per phase covering its row range
//! of *all* B matrices (B·d_i rows instead of d_i), and transposes
//! remain per-matrix (they are matrix-local permutations).
//!
//! Bit-exactness: every row is transformed by the same per-row kernel
//! with the same plan regardless of how rows are chunked across threads
//! or batches (see `native_engine_thread_count_invariant`), and the
//! gather/scatter copies are value-preserving — so a batched execution
//! produces byte-identical planes to B single-shot
//! [`PlannedTransform::execute`] runs. `service_integration.rs` asserts
//! this against both the single-shot driver and the `dft2d` oracle.
//! Because every row is transformed identically no matter which group
//! owns it, a *re-partition* (model drift → new `d`) never changes the
//! produced values on unpadded plans — outputs stay bit-exact across
//! re-planning.
//!
//! Timing contract: one call = one whole-batch measurement. The service
//! executor wraps this call in a wall clock and feeds `elapsed / B`
//! into the engine's [`crate::model::OnlineModel`] at the
//! whole-request observation point — the free `(x, y, t)` sample every
//! served batch provides.

use crate::coordinator::engine::{EngineError, RowFftEngine};
use crate::coordinator::group::row_offsets;
use crate::coordinator::plan::{PhaseTimings, PlannedTransform};
use crate::dft::fft::Direction;
use crate::dft::pipeline::{default_mode, PipelineMode};
use crate::dft::transpose::transpose_in_place_parallel;
use crate::dft::SignalMatrix;

/// Execute `plan` over every matrix in `mats` (all must be n×n) under
/// the process-wide [`PipelineMode`].
pub fn execute_planned_batch(
    engine: &dyn RowFftEngine,
    plan: &PlannedTransform,
    mats: &mut [&mut SignalMatrix],
    threads_per_group: usize,
    transpose_block: usize,
) -> Result<(), EngineError> {
    execute_planned_batch_with_mode(
        engine,
        plan,
        mats,
        threads_per_group,
        transpose_block,
        default_mode(),
    )
    .map(|_| ())
}

/// [`execute_planned_batch`] with an explicit mode, returning the
/// per-phase timings the serving executor feeds into the online model
/// (fused: summed tile busy seconds; barrier: row-FFT wall vs
/// transpose wall — see [`PhaseTimings`]).
pub fn execute_planned_batch_with_mode(
    engine: &dyn RowFftEngine,
    plan: &PlannedTransform,
    mats: &mut [&mut SignalMatrix],
    threads_per_group: usize,
    transpose_block: usize,
    mode: PipelineMode,
) -> Result<PhaseTimings, EngineError> {
    let n = plan.n;
    for m in mats.iter() {
        assert_eq!((m.rows, m.cols), (n, n), "batch matrix shape mismatch");
    }
    assert_eq!(plan.d.iter().sum::<usize>(), n, "plan distribution must cover all rows");
    if mats.is_empty() {
        return Ok(PhaseTimings::default());
    }
    let total_threads = plan.groups() * threads_per_group.max(1);
    match mode {
        // compiling the tile schedule here is O(tiles) pushes per batch
        // — dwarfed by the transform itself and by the WisdomRecord
        // clone the dispatcher already pays; memoizing the compiled
        // pipeline in the wisdom record is a future optimization
        PipelineMode::Fused => plan.pipeline().execute_batch(engine, mats, total_threads),
        PipelineMode::Barrier => {
            let mut row_s = 0.0;
            let mut col_s = 0.0;
            for _phase in 0..2 {
                let t0 = std::time::Instant::now();
                row_phase_batch(engine, plan, mats, threads_per_group)?;
                row_s += t0.elapsed().as_secs_f64();
                let t0 = std::time::Instant::now();
                for m in mats.iter_mut() {
                    transpose_in_place_parallel(m, transpose_block, total_threads);
                }
                col_s += t0.elapsed().as_secs_f64();
            }
            Ok(PhaseTimings { row_s, col_s })
        }
    }
}

/// One row phase across the whole batch: group i gets the i-th row
/// slice of every matrix and runs them as a single engine call.
fn row_phase_batch(
    engine: &dyn RowFftEngine,
    plan: &PlannedTransform,
    mats: &mut [&mut SignalMatrix],
    threads_per_group: usize,
) -> Result<(), EngineError> {
    let n = plan.n;
    let d = &plan.d;
    let pad_lens = plan.pad_lens();
    let offsets = row_offsets(d);
    let p = d.len();

    // carve each matrix's planes into per-group row slices, regrouped
    // per group so one thread owns group i's slice of every matrix
    let mut per_group: Vec<Vec<(&mut [f64], &mut [f64])>> =
        (0..p).map(|_| Vec::with_capacity(mats.len())).collect();
    for m in mats.iter_mut() {
        let mm: &mut SignalMatrix = &mut **m;
        let mut re_rest: &mut [f64] = &mut mm.re;
        let mut im_rest: &mut [f64] = &mut mm.im;
        for (i, group) in per_group.iter_mut().enumerate() {
            let len = (offsets[i + 1] - offsets[i]) * n;
            let (re_here, re_next) = re_rest.split_at_mut(len);
            let (im_here, im_next) = im_rest.split_at_mut(len);
            re_rest = re_next;
            im_rest = im_next;
            group.push((re_here, im_here));
        }
    }

    let errors: std::sync::Mutex<Vec<EngineError>> = std::sync::Mutex::new(Vec::new());
    let mut jobs: Vec<crate::dft::exec::Job> = Vec::with_capacity(p);
    for (i, slices) in per_group.into_iter().enumerate() {
        let rows = d[i];
        if rows == 0 {
            continue;
        }
        let pad = pad_lens[i];
        let errors = &errors;
        jobs.push(Box::new(move || {
            if let Err(e) = group_ffts(engine, slices, rows, n, pad, threads_per_group) {
                errors.lock().unwrap().push(e);
            }
        }));
    }
    crate::dft::exec::ExecCtx::global().run_jobs(jobs);
    match errors.into_inner().unwrap().into_iter().next() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Group i's work for one phase: B row slices of `rows` rows each. The
/// single-matrix unpadded case runs in place; otherwise the slices are
/// gathered into one (B·rows × pad) work matrix (Algorithm 7's local
/// padded buffer, batch-widened) leased from the calling thread's
/// scratch arena, transformed in one engine call, and scattered back —
/// a warm serve loop performs no work-matrix allocation.
fn group_ffts(
    engine: &dyn RowFftEngine,
    mut slices: Vec<(&mut [f64], &mut [f64])>,
    rows: usize,
    n: usize,
    pad: usize,
    threads: usize,
) -> Result<(), EngineError> {
    debug_assert!(pad >= n);
    if slices.len() == 1 && pad == n {
        let (re, im) = &mut slices[0];
        return engine.fft_rows(re, im, rows, n, Direction::Forward, threads);
    }
    let b = slices.len();
    crate::dft::exec::with_scratch(|scratch| {
        let (wre, wim) = scratch.pair(b * rows * pad);
        for (j, (re, im)) in slices.iter().enumerate() {
            for r in 0..rows {
                let dst = (j * rows + r) * pad;
                wre[dst..dst + n].copy_from_slice(&re[r * n..(r + 1) * n]);
                wim[dst..dst + n].copy_from_slice(&im[r * n..(r + 1) * n]);
            }
        }
        engine.fft_rows(wre, wim, b * rows, pad, Direction::Forward, threads)?;
        for (j, (re, im)) in slices.iter_mut().enumerate() {
            for r in 0..rows {
                let src = (j * rows + r) * pad;
                re[r * n..(r + 1) * n].copy_from_slice(&wre[src..src + n]);
                im[r * n..(r + 1) * n].copy_from_slice(&wim[src..src + n]);
            }
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::coordinator::fpm::SpeedFunction;
    use crate::coordinator::pad::PadCost;

    fn plan_for(n: usize, speeds: &[f64], pad: bool) -> PlannedTransform {
        let fpms: Vec<SpeedFunction> = speeds
            .iter()
            .enumerate()
            .map(|(g, &s)| {
                SpeedFunction::from_fn(
                    &format!("g{g}"),
                    (1..=8).map(|k| k * n / 8).collect(),
                    vec![n, n + 8],
                    move |_, y| Some(if y > n { s * 2.0 } else { s }),
                )
            })
            .collect();
        PlannedTransform::from_fpms(&fpms, n, 0.05, pad.then_some(PadCost::PaperRatio)).unwrap()
    }

    #[test]
    fn batch_of_one_matches_single_shot_bitwise() {
        let n = 32;
        let plan = plan_for(n, &[100.0, 100.0], false);
        let orig = SignalMatrix::random(n, n, 1);
        let mut single = orig.clone();
        plan.execute(&NativeEngine, &mut single, 2, 64).unwrap();
        let mut batched = orig.clone();
        execute_planned_batch(&NativeEngine, &plan, &mut [&mut batched], 2, 64).unwrap();
        assert_eq!(batched.max_abs_diff(&single), 0.0, "batch-of-one must be bit-exact");
    }

    #[test]
    fn batch_of_many_matches_single_shot_bitwise() {
        let n = 16;
        let plan = plan_for(n, &[100.0, 300.0], false);
        let origs: Vec<SignalMatrix> = (0..4).map(|s| SignalMatrix::random(n, n, s)).collect();
        let mut singles = origs.clone();
        for m in singles.iter_mut() {
            plan.execute(&NativeEngine, m, 1, 64).unwrap();
        }
        let mut batched = origs.clone();
        {
            let mut refs: Vec<&mut SignalMatrix> = batched.iter_mut().collect();
            execute_planned_batch(&NativeEngine, &plan, &mut refs, 1, 64).unwrap();
        }
        for (b, s) in batched.iter().zip(&singles) {
            assert_eq!(b.max_abs_diff(s), 0.0);
        }
    }

    #[test]
    fn padded_batch_matches_single_shot_bitwise() {
        let n = 16;
        let plan = plan_for(n, &[100.0, 100.0], true);
        assert!(plan.is_padded(), "test setup must choose a pad");
        let origs: Vec<SignalMatrix> = (10..13).map(|s| SignalMatrix::random(n, n, s)).collect();
        let mut singles = origs.clone();
        for m in singles.iter_mut() {
            plan.execute(&NativeEngine, m, 1, 64).unwrap();
        }
        let mut batched = origs.clone();
        {
            let mut refs: Vec<&mut SignalMatrix> = batched.iter_mut().collect();
            execute_planned_batch(&NativeEngine, &plan, &mut refs, 1, 64).unwrap();
        }
        for (b, s) in batched.iter().zip(&singles) {
            assert_eq!(b.max_abs_diff(s), 0.0);
        }
    }

    #[test]
    fn fused_batch_matches_barrier_batch_bitwise() {
        for padded in [false, true] {
            let n = 16;
            let plan = plan_for(n, &[100.0, 100.0], padded);
            assert_eq!(plan.is_padded(), padded, "test setup");
            let origs: Vec<SignalMatrix> =
                (20..23).map(|s| SignalMatrix::random(n, n, s)).collect();
            let mut fused = origs.clone();
            let mut barrier = origs.clone();
            {
                let mut refs: Vec<&mut SignalMatrix> = fused.iter_mut().collect();
                let t = execute_planned_batch_with_mode(
                    &NativeEngine,
                    &plan,
                    &mut refs,
                    1,
                    64,
                    crate::dft::pipeline::PipelineMode::Fused,
                )
                .unwrap();
                assert!(t.row_s >= 0.0 && t.col_s >= 0.0);
            }
            {
                let mut refs: Vec<&mut SignalMatrix> = barrier.iter_mut().collect();
                execute_planned_batch_with_mode(
                    &NativeEngine,
                    &plan,
                    &mut refs,
                    1,
                    64,
                    crate::dft::pipeline::PipelineMode::Barrier,
                )
                .unwrap();
            }
            for (f, b) in fused.iter().zip(&barrier) {
                assert_eq!(f.max_abs_diff(b), 0.0, "padded={padded}");
            }
        }
    }

    #[test]
    fn zero_row_groups_skipped() {
        let n = 8;
        let plan = PlannedTransform {
            n,
            d: vec![0, 8, 0],
            pads: vec![
                crate::coordinator::pad::PadDecision { n_padded: n, t_unpadded: 0.0, t_padded: 0.0 };
                3
            ],
            algorithm: crate::coordinator::partition::Algorithm::Balanced,
            makespan: f64::NAN,
            kind: crate::dft::real::TransformKind::C2c,
        };
        let orig = SignalMatrix::random(n, n, 2);
        let mut got = orig.clone();
        execute_planned_batch(&NativeEngine, &plan, &mut [&mut got], 1, 64).unwrap();
        let want = crate::dft::naive_dft2d(&orig);
        let err = got.max_abs_diff(&want) / want.norm().max(1.0);
        assert!(err < 1e-10, "rel err {err}");
    }

    #[test]
    fn empty_batch_is_noop() {
        let plan = plan_for(16, &[100.0, 100.0], false);
        execute_planned_batch(&NativeEngine, &plan, &mut [], 1, 64).unwrap();
    }
}
