//! Wisdom store — memoized planning artifacts, FFTW-style.
//!
//! The expensive inputs of a PFFT run — FPM construction (the paper's
//! "96-hour surface" problem, §V) and the POPTA/HPOPTA + pad search —
//! depend only on `(engine, N, p, kind)`, never on the signal. The
//! store memoizes one [`WisdomRecord`] per key and persists the whole
//! map as JSON via [`crate::util::json`], so a restarted server skips
//! re-planning entirely (the analogue of `fftw_import_wisdom`).
//!
//! Records are keyed per [`TransformKind`] plane: real (r2c) planes run
//! roughly 2x faster than c2c, so their measured surfaces — and hence
//! their POPTA/HPOPTA partitions and pad choices — are separate
//! artifacts. The JSON artifact is **version 5**: engine names are
//! parsed into typed [`EngineId`]s (the persisted spellings are
//! unchanged, so older files parse forward losslessly) and the engine
//! portfolio's per-`(engine, n, kind)` cost surfaces persist as a
//! `portfolio` object. Version-4 files load with an empty portfolio,
//! version-3 files additionally load with no tiles — the executor
//! falls back to the modeled width — and version-2 files additionally
//! load with every record as c2c.

use std::collections::BTreeMap;
use std::path::Path;

use crate::coordinator::engine::{EngineId, RowFftEngine};
use crate::coordinator::group::GroupConfig;
use crate::coordinator::pad::{PadCost, PadDecision};
use crate::coordinator::partition::Algorithm;
use crate::coordinator::plan::PlannedTransform;
use crate::dft::real::TransformKind;
use crate::model::{OnlineModel, PerfModel, PortfolioModel};
use crate::profiler::{build_fpms_with, ProfileSpec};
use crate::simulator::vexec::predict_point;
use crate::simulator::Package;
use crate::util::json::Json;

/// Flat speed assumption (MFLOPs) for cost estimates before any wisdom
/// exists for a key — deliberately modest so unplanned work is not
/// starved by the shortest-predicted-job-first queue.
pub const DEFAULT_MFLOPS: f64 = 500.0;

/// Knobs for on-demand (measured) planning inside the service.
#[derive(Clone, Debug)]
pub struct PlanningConfig {
    /// abstract processors p
    pub groups: usize,
    /// threads per group t
    pub threads_per_group: usize,
    /// ε for the Step-1b identity test
    pub eps: f64,
    /// pad search (None = exact row length, the serving default — padding
    /// trades exactness for speed, see `coordinator::pad` docs)
    pub pad_cost: Option<PadCost>,
    /// points on the x (rows) grid when profiling the y = N plane
    pub profile_points: usize,
    /// MeanUsingTtest repetition divisor while profiling
    pub rep_scale: usize,
    /// wall-clock budget for one FPM build (partial-FPM cutoff)
    pub profile_budget_s: f64,
}

impl Default for PlanningConfig {
    fn default() -> Self {
        PlanningConfig {
            groups: 2,
            threads_per_group: 2,
            eps: 0.05,
            pad_cost: None,
            profile_points: 4,
            rep_scale: 2000,
            profile_budget_s: 1.5,
        }
    }
}

/// Pads loaded from disk may be corrupt; cap how far above N a
/// persisted pad is allowed to reach (the paper's search window is
/// 4096; this leaves generous slack without permitting multi-GiB
/// work-buffer allocations from a hand-edited file).
pub const MAX_PAD_ABOVE_N: usize = 1 << 20;

/// The pad search window above N (the paper's §V-B grid reaches 4
/// steps of 128 beyond the problem size).
pub const PAD_SEARCH_WINDOW: usize = 512;

/// One memoized planning outcome for `(engine, n, p)`.
#[derive(Clone, Debug, PartialEq)]
pub struct WisdomRecord {
    pub engine: EngineId,
    pub n: usize,
    /// abstract processors the plan targets
    pub p: usize,
    /// threads per group used while profiling
    pub t: usize,
    pub eps: f64,
    pub plan: PlannedTransform,
    /// predicted whole-request seconds (FPM-informed scheduling weight)
    pub predicted_cost_s: f64,
    /// the row-kernel factor schedule the executor chose for `n`
    /// (ascending {2,3,5} factors; empty = non-smooth, Bluestein)
    pub factors: Vec<usize>,
    /// the measured speed surfaces the plan came from — the paper's
    /// expensive §V artifact, persisted so a restarted server can
    /// re-plan (new ε, pad policy, ...) without re-measuring. Empty for
    /// simulator-backed records (their surfaces are recomputable).
    pub fpms: Vec<crate::coordinator::fpm::SpeedFunction>,
    /// the row-kernel generation
    /// ([`crate::dft::radix::kernel_generation`]) the surfaces were
    /// measured against. Native records tagged with a *different*
    /// non-empty generation are treated as stale at lookup (the kernel
    /// they price no longer exists), forcing a re-measure; legacy
    /// records carry the empty string and stay valid.
    pub kernel_gen: String,
}

impl WisdomRecord {
    /// Key inside the store. The transform kind lives on the plan — a
    /// record plans exactly one (engine, N, p, kind) plane.
    pub fn key(&self) -> WisdomKey {
        (self.engine, self.n, self.p, self.plan.kind)
    }

    /// The transform kind this record's plan targets.
    pub fn kind(&self) -> TransformKind {
        self.plan.kind
    }

    /// Plan by *measuring* a real engine: build the y = N plane with the
    /// paper's methodology (budget-capped partial FPM), then POPTA/HPOPTA
    /// (+ pad search when configured). Falls back to the balanced
    /// distribution on degenerate profiling outcomes rather than failing
    /// the request.
    pub fn from_measurement(
        engine_label: EngineId,
        engine: &dyn RowFftEngine,
        n: usize,
        cfg: &PlanningConfig,
    ) -> WisdomRecord {
        Self::from_measurement_sampled(engine_label, engine, n, cfg, TransformKind::C2c).0
    }

    /// [`from_measurement`](WisdomRecord::from_measurement) for an
    /// explicit transform kind: real (r2c) planes are profiled with the
    /// r2c pair kernel, so their surfaces — and the partitions planned
    /// over them — reflect the real path's ~2x row-phase speed.
    pub fn from_measurement_kind(
        engine_label: EngineId,
        engine: &dyn RowFftEngine,
        n: usize,
        cfg: &PlanningConfig,
        kind: TransformKind,
    ) -> WisdomRecord {
        Self::from_measurement_sampled(engine_label, engine, n, cfg, kind).0
    }

    /// [`from_measurement`](WisdomRecord::from_measurement) that also
    /// returns the raw profiling samples `(x, y, mean seconds)` so the
    /// caller can fold them into an [`OnlineModel`] — the profiler emits
    /// into the same store the serving executor appends to. Each sample
    /// is *per group*: the mean seconds for one of the p concurrent
    /// groups to execute x row-FFTs of length y. A caller feeding a
    /// platform-level model must rescale the row count to p·x (see the
    /// service's `plan_for`).
    pub fn from_measurement_sampled(
        engine_label: EngineId,
        engine: &dyn RowFftEngine,
        n: usize,
        cfg: &PlanningConfig,
        kind: TransformKind,
    ) -> (WisdomRecord, Vec<(usize, usize, f64)>) {
        let kind = kind.plan_kind();
        let points = cfg.profile_points.clamp(2, n.max(2));
        let mut xs: Vec<usize> = (1..=points).map(|k| (k * n / points).max(1)).collect();
        xs.dedup();
        let mut ys = vec![n];
        if cfg.pad_cost.is_some() {
            // pad candidates above N come from the engine so the search
            // only prices lengths the engine is fast at (the native
            // engine restricts to 5-smooth points of the 128-grid)
            ys.extend(engine.pad_candidates(n, PAD_SEARCH_WINDOW));
        }
        let mut spec = ProfileSpec::new(xs, ys, GroupConfig::new(cfg.groups, cfg.threads_per_group));
        spec.rep_scale = cfg.rep_scale.max(1);
        spec.budget_s = cfg.profile_budget_s;
        spec.kind = kind;
        let mut samples: Vec<(usize, usize, f64)> = Vec::new();
        let fpms = build_fpms_with(engine, &spec, |x, y, t| samples.push((x, y, t)));
        let plan = PlannedTransform::from_fpms(&fpms, n, cfg.eps, cfg.pad_cost)
            .unwrap_or_else(|_| PlannedTransform::balanced_fallback(cfg.groups, n))
            .with_kind(kind);
        let predicted_cost_s = plan.predicted_seconds(DEFAULT_MFLOPS);
        let rec = WisdomRecord {
            engine: engine_label,
            n,
            p: cfg.groups,
            t: cfg.threads_per_group,
            eps: cfg.eps,
            plan,
            predicted_cost_s,
            factors: crate::dft::radix::factorize_235(n).unwrap_or_default(),
            fpms,
            kernel_gen: crate::dft::radix::kernel_generation().to_string(),
        };
        (rec, samples)
    }

    /// Re-plan from a live [`OnlineModel`]: POPTA/HPOPTA + pad selection
    /// run against the model's *refreshed* sections (base sections
    /// rescaled to the observed machine speed), and the predicted cost
    /// comes from the model's refined whole-request estimate when it has
    /// one. This is the drift-recovery path — no re-measurement needed.
    #[allow(clippy::too_many_arguments)]
    pub fn from_model(
        engine_label: EngineId,
        model: &OnlineModel,
        n: usize,
        p: usize,
        t: usize,
        eps: f64,
        pad_cost: Option<PadCost>,
        pad_window: usize,
    ) -> WisdomRecord {
        Self::from_model_kind(
            engine_label,
            model,
            n,
            p,
            t,
            eps,
            pad_cost,
            pad_window,
            TransformKind::C2c,
        )
    }

    /// [`from_model`](WisdomRecord::from_model) for an explicit kind:
    /// the drift-recovery replan of a real-plane record runs against
    /// the *real* model stream's refreshed sections.
    #[allow(clippy::too_many_arguments)]
    pub fn from_model_kind(
        engine_label: EngineId,
        model: &OnlineModel,
        n: usize,
        p: usize,
        t: usize,
        eps: f64,
        pad_cost: Option<PadCost>,
        pad_window: usize,
        kind: TransformKind,
    ) -> WisdomRecord {
        let kind = kind.plan_kind();
        let plan = if model.groups() == 0 {
            // no base model attached: sections are empty, fall back
            PlannedTransform::balanced_fallback(p, n)
        } else {
            PlannedTransform::from_model(model, n, eps, pad_cost, pad_window)
                .unwrap_or_else(|_| PlannedTransform::balanced_fallback(p, n))
        }
        .with_kind(kind);
        // cost source order: refined whole-request estimate, then the
        // model's (speed-rescaled) base prediction, then the plan's own
        // makespan-derived estimate — never a flat guess while the model
        // can do better
        let predicted_cost_s = model
            .refined_time(2 * n, n)
            .or_else(|| model.predict_time(2 * n, n))
            .unwrap_or_else(|| plan.predicted_seconds(DEFAULT_MFLOPS));
        WisdomRecord {
            engine: engine_label,
            n,
            p,
            t,
            eps,
            plan,
            predicted_cost_s,
            factors: crate::dft::radix::factorize_235(n).unwrap_or_default(),
            fpms: Vec::new(),
            kernel_gen: crate::dft::radix::kernel_generation().to_string(),
        }
    }

    /// Plan deterministically from the virtual testbed (no measurement,
    /// instant even at paper scale) — the service's virtual-time path.
    pub fn from_simulator(package: Package, n: usize, pad: bool) -> WisdomRecord {
        let point = predict_point(package, n);
        let cfg = package.best_groups();
        let pads: Vec<PadDecision> = point
            .d
            .iter()
            .zip(&point.pads)
            .map(|(_, &v)| PadDecision {
                n_padded: if pad { v } else { n },
                t_unpadded: 0.0,
                t_padded: 0.0,
            })
            .collect();
        let plan = PlannedTransform {
            n,
            d: point.d.clone(),
            pads,
            algorithm: if point.used_hpopta { Algorithm::Hpopta } else { Algorithm::Popta },
            makespan: f64::NAN,
            kind: TransformKind::C2c,
        };
        WisdomRecord {
            engine: EngineId::Sim(package),
            n,
            p: cfg.p,
            t: cfg.t,
            eps: crate::simulator::vexec::EPS_IDENTICAL,
            plan,
            predicted_cost_s: if pad { point.t_pad } else { point.t_fpm },
            factors: crate::dft::radix::factorize_235(n).unwrap_or_default(),
            fpms: Vec::new(),
            kernel_gen: crate::dft::radix::kernel_generation().to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        let pads: Vec<Json> = self
            .plan
            .pads
            .iter()
            .map(|p| {
                Json::obj()
                    .set("n_padded", p.n_padded)
                    .set("t_unpadded", p.t_unpadded)
                    .set("t_padded", p.t_padded)
            })
            .collect();
        let fpms: Vec<Json> = self.fpms.iter().map(|f| f.to_json()).collect();
        Json::obj()
            .set("engine", self.engine.as_str())
            .set("n", self.n)
            .set("p", self.p)
            .set("t", self.t)
            .set("eps", self.eps)
            .set("kind", self.plan.kind.name())
            .set("algorithm", self.plan.algorithm.name())
            .set("d", self.plan.d.clone())
            .set("pads", Json::Arr(pads))
            .set("makespan", Json::Num(self.plan.makespan))
            .set("predicted_cost_s", self.predicted_cost_s)
            .set("factors", self.factors.clone())
            .set("kernel", self.kernel_gen.as_str())
            .set("fpms", Json::Arr(fpms))
    }

    pub fn from_json(j: &Json) -> Result<WisdomRecord, String> {
        let str_field = |k: &str| {
            j.get(k).and_then(Json::as_str).map(str::to_string).ok_or(format!("wisdom: missing {k}"))
        };
        let usize_field = |k: &str| {
            j.get(k).and_then(Json::as_usize).ok_or(format!("wisdom: missing {k}"))
        };
        let f64_field = |k: &str| j.get(k).and_then(Json::as_f64).ok_or(format!("wisdom: missing {k}"));
        // persisted spellings are the canonical `EngineId` strings (and
        // every historical alias `EngineId::parse` accepts) — unknown
        // names are corrupt, not silently kept
        let engine_str = str_field("engine")?;
        let engine = EngineId::parse(&engine_str)
            .ok_or_else(|| format!("wisdom: unknown engine `{engine_str}`"))?;
        let n = usize_field("n")?;
        let p = usize_field("p")?;
        let t = usize_field("t")?;
        let eps = f64_field("eps")?;
        // the kind field arrived with JSON v3 — v2 records are all c2c;
        // an unparsable value is corrupt, not legacy
        let kind = match j.get("kind").and_then(Json::as_str) {
            Some(s) => TransformKind::parse(s).ok_or(format!("wisdom: bad kind `{s}`"))?,
            None => TransformKind::C2c,
        };
        let algorithm = Algorithm::parse(&str_field("algorithm")?)
            .ok_or_else(|| "wisdom: bad algorithm".to_string())?;
        let d: Vec<usize> = j
            .get("d")
            .and_then(Json::as_arr)
            .ok_or("wisdom: missing d")?
            .iter()
            .map(|v| v.as_usize().ok_or("wisdom: bad d entry".to_string()))
            .collect::<Result<_, _>>()?;
        let pads: Vec<PadDecision> = j
            .get("pads")
            .and_then(Json::as_arr)
            .ok_or("wisdom: missing pads")?
            .iter()
            .map(|pj| -> Result<PadDecision, String> {
                Ok(PadDecision {
                    n_padded: pj
                        .get("n_padded")
                        .and_then(Json::as_usize)
                        .ok_or("wisdom: bad pad")?,
                    t_unpadded: pj.get("t_unpadded").and_then(Json::as_f64).unwrap_or(0.0),
                    t_padded: pj.get("t_padded").and_then(Json::as_f64).unwrap_or(0.0),
                })
            })
            .collect::<Result<_, _>>()?;
        if d.len() != pads.len() {
            return Err("wisdom: d/pads arity mismatch".to_string());
        }
        if d.iter().sum::<usize>() != n {
            return Err(format!("wisdom: d sums to {} != n {n}", d.iter().sum::<usize>()));
        }
        // the drivers assert n <= pad at execution time; reject corrupt
        // pads at load time instead of panicking a worker later (and cap
        // them so a hand-edited file cannot demand a huge work buffer)
        for pd in &pads {
            if pd.n_padded < n || pd.n_padded > n.saturating_add(MAX_PAD_ABOVE_N) {
                return Err(format!(
                    "wisdom: pad length {} out of range [{n}, {}]",
                    pd.n_padded,
                    n.saturating_add(MAX_PAD_ABOVE_N)
                ));
            }
        }
        // NaN makespans serialize as null (JSON has no NaN)
        let makespan = j.get("makespan").and_then(Json::as_f64).unwrap_or(f64::NAN);
        let predicted_cost_s = f64_field("predicted_cost_s")?;
        // factor schedule: informational in the JSON artifact (it is
        // fully derivable from n), so it is always recomputed on load —
        // a stale or hand-edited field can never poison the executor,
        // and legacy files without it load identically
        let factors = crate::dft::radix::factorize_235(n).unwrap_or_default();
        // kernel-generation tag: absent on legacy files (empty = "was
        // measured before kernels were tagged" — accepted at lookup)
        let kernel_gen =
            j.get("kernel").and_then(Json::as_str).unwrap_or_default().to_string();
        // fpms are optional (older files / simulator records have none)
        let fpms = match j.get("fpms").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(crate::coordinator::fpm::SpeedFunction::from_json)
                .collect::<Result<Vec<_>, _>>()?,
            None => Vec::new(),
        };
        Ok(WisdomRecord {
            engine,
            n,
            p,
            t,
            eps,
            plan: PlannedTransform { n, d, pads, algorithm, makespan, kind },
            predicted_cost_s,
            factors,
            fpms,
            kernel_gen,
        })
    }

    /// Warm the plan cache for every row length this record can touch
    /// (the "dft plan handles" part of the wisdom) — mixed-radix plans
    /// for 5-smooth lengths, Bluestein state otherwise, exactly the
    /// executor's dispatch.
    pub fn warm_plan_cache(&self) {
        let mut lens = self.plan.pad_lens();
        lens.push(self.n);
        lens.sort_unstable();
        lens.dedup();
        for len in lens {
            if len == 0 {
                continue;
            }
            let _ = crate::dft::plan::PlanCache::global().row_plan(len);
        }
    }
}

/// `(engine, n, p, kind)` — what a plan depends on.
pub type WisdomKey = (EngineId, usize, usize, TransformKind);

/// One measured row-tile width — the winner of the executor's one-shot
/// micro-calibration ([`crate::dft::exec::calibrate_row_tile`]) for a
/// row length, persisted so a restarted server seeds its tile cache
/// instead of re-timing the widths on the first cold plan.
#[derive(Clone, Debug, PartialEq)]
pub struct TileRecord {
    /// the row length the widths were timed at
    pub n: usize,
    /// the transform-kind plane the calibration ran under (c2r shares
    /// the r2c plane, exactly like [`WisdomRecord`] keys)
    pub kind: TransformKind,
    /// the row-kernel generation the timing ran against
    /// ([`crate::dft::radix::kernel_generation`]); a *different*
    /// non-empty tag is stale at lookup — the kernel the width was
    /// measured for no longer exists
    pub kernel: String,
    /// the winning tile width (1..=8)
    pub width: usize,
}

/// The persistent map of planning outcomes, plus the per-engine online
/// model deltas + drift log, the measured row-tile widths and the
/// engine portfolio's cost surfaces. JSON artifact version 5
/// (`portfolio` object); version-4 files load with an empty portfolio,
/// version-3 files additionally load with no tiles, version-2 files
/// additionally load with every record as c2c, version-1 files
/// additionally load with no model state.
#[derive(Clone, Debug, Default)]
pub struct WisdomStore {
    records: BTreeMap<WisdomKey, WisdomRecord>,
    models: BTreeMap<String, OnlineModel>,
    tiles: BTreeMap<(usize, TransformKind), TileRecord>,
    portfolio: Option<PortfolioModel>,
}

impl WisdomStore {
    pub fn new() -> WisdomStore {
        WisdomStore::default()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Lookup of a c2c plan (the overwhelmingly common key shape).
    pub fn get(&self, engine: EngineId, n: usize, p: usize) -> Option<&WisdomRecord> {
        self.get_kind(engine, n, p, TransformKind::C2c)
    }

    /// Kind-keyed lookup (real planes are separate artifacts). Native
    /// records measured against a different row-kernel generation (see
    /// [`crate::dft::radix::kernel_generation`]) miss here: their FPM
    /// surfaces price a kernel that no longer exists, so the caller
    /// pays a fresh profiling event and POPTA/HPOPTA re-partitions
    /// against the installed kernel's speed curve. Untagged (legacy)
    /// records and non-native engines are exempt — simulator surfaces
    /// do not depend on the native kernel.
    pub fn get_kind(
        &self,
        engine: EngineId,
        n: usize,
        p: usize,
        kind: TransformKind,
    ) -> Option<&WisdomRecord> {
        let rec = self.records.get(&(engine, n, p, kind.plan_kind()))?;
        if rec.engine == EngineId::Native
            && !rec.kernel_gen.is_empty()
            && rec.kernel_gen != crate::dft::radix::kernel_generation()
        {
            return None;
        }
        Some(rec)
    }

    /// Insert (replacing any previous record for the key).
    pub fn insert(&mut self, rec: WisdomRecord) {
        self.records.insert(rec.key(), rec);
    }

    /// Drop a record (drift invalidation): the next request for the key
    /// pays a fresh planning event against the refreshed model.
    pub fn remove(
        &mut self,
        engine: EngineId,
        n: usize,
        p: usize,
        kind: TransformKind,
    ) -> Option<WisdomRecord> {
        self.records.remove(&(engine, n, p, kind.plan_kind()))
    }

    pub fn iter(&self) -> impl Iterator<Item = &WisdomRecord> {
        self.records.values()
    }

    /// Attach/replace an engine's persisted online-model state.
    pub fn set_model(&mut self, engine: &str, model: OnlineModel) {
        self.models.insert(engine.to_string(), model);
    }

    pub fn model(&self, engine: &str) -> Option<&OnlineModel> {
        self.models.get(engine)
    }

    pub fn models(&self) -> impl Iterator<Item = (&String, &OnlineModel)> {
        self.models.iter()
    }

    /// Record a measured row-tile width, stamped with the installed
    /// kernel generation (re-measuring re-stamps).
    pub fn set_tile(&mut self, n: usize, kind: TransformKind, width: usize) {
        let kind = kind.plan_kind();
        self.tiles.insert(
            (n, kind),
            TileRecord {
                n,
                kind,
                kernel: crate::dft::radix::kernel_generation().to_string(),
                width: width.clamp(1, 8),
            },
        );
    }

    /// The measured tile width for a row length, or `None` when none
    /// was recorded *or* the record was timed against a different
    /// row-kernel generation — same staleness rule as
    /// [`get_kind`](WisdomStore::get_kind), so a kernel upgrade forces
    /// a re-calibration rather than applying a width tuned for a
    /// retired kernel's port pressure.
    pub fn tile_width(&self, n: usize, kind: TransformKind) -> Option<usize> {
        let rec = self.tiles.get(&(n, kind.plan_kind()))?;
        if !rec.kernel.is_empty() && rec.kernel != crate::dft::radix::kernel_generation() {
            return None;
        }
        Some(rec.width)
    }

    /// Drop a measured tile width (memory-class drift invalidation:
    /// the cache hierarchy the timing saw has changed).
    pub fn clear_tile(&mut self, n: usize, kind: TransformKind) -> Option<TileRecord> {
        self.tiles.remove(&(n, kind.plan_kind()))
    }

    pub fn tiles(&self) -> impl Iterator<Item = &TileRecord> {
        self.tiles.values()
    }

    /// Attach/replace the persisted engine-portfolio state (cost
    /// surfaces + sticky picks).
    pub fn set_portfolio(&mut self, portfolio: PortfolioModel) {
        self.portfolio = Some(portfolio);
    }

    pub fn portfolio(&self) -> Option<&PortfolioModel> {
        self.portfolio.as_ref()
    }

    pub fn take_portfolio(&mut self) -> Option<PortfolioModel> {
        self.portfolio.take()
    }

    pub fn to_json(&self) -> Json {
        let recs: Vec<Json> = self.records.values().map(WisdomRecord::to_json).collect();
        let models: Vec<Json> = self
            .models
            .iter()
            .map(|(e, m)| Json::obj().set("engine", e.as_str()).set("model", m.to_json()))
            .collect();
        let tiles: Vec<Json> = self
            .tiles
            .values()
            .map(|t| {
                Json::obj()
                    .set("n", t.n)
                    .set("kind", t.kind.name())
                    .set("kernel", t.kernel.as_str())
                    .set("width", t.width)
            })
            .collect();
        let mut out = Json::obj()
            .set("version", 5i64)
            .set("records", Json::Arr(recs))
            .set("models", Json::Arr(models))
            .set("tiles", Json::Arr(tiles));
        if let Some(p) = &self.portfolio {
            if !p.is_empty() {
                out = out.set("portfolio", p.to_json());
            }
        }
        out
    }

    pub fn from_json(j: &Json) -> Result<WisdomStore, String> {
        let mut store = WisdomStore::new();
        let recs = j.get("records").and_then(Json::as_arr).ok_or("wisdom: missing records")?;
        for r in recs {
            store.insert(WisdomRecord::from_json(r)?);
        }
        // model deltas are optional (version-1 files have none)
        for mj in j.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
            let engine = mj
                .get("engine")
                .and_then(Json::as_str)
                .ok_or("wisdom: model entry missing engine")?;
            let model = OnlineModel::from_json(
                mj.get("model").ok_or("wisdom: model entry missing model")?,
            )?;
            store.models.insert(engine.to_string(), model);
        }
        // measured tile widths arrived with JSON v4 — older files load
        // with none (the executor then falls back to the modeled
        // width); a malformed entry is corrupt, not legacy
        for tj in j.get("tiles").and_then(Json::as_arr).unwrap_or(&[]) {
            let n = tj
                .get("n")
                .and_then(Json::as_usize)
                .ok_or("wisdom: tile entry missing n")?;
            let kind = match tj.get("kind").and_then(Json::as_str) {
                Some(s) => {
                    TransformKind::parse(s).ok_or(format!("wisdom: bad tile kind `{s}`"))?
                }
                None => TransformKind::C2c,
            };
            let width = tj
                .get("width")
                .and_then(Json::as_usize)
                .ok_or("wisdom: tile entry missing width")?;
            if width == 0 || width > 64 {
                return Err(format!("wisdom: tile width {width} out of range for n {n}"));
            }
            let kernel =
                tj.get("kernel").and_then(Json::as_str).unwrap_or_default().to_string();
            let kind = kind.plan_kind();
            store.tiles.insert((n, kind), TileRecord { n, kind, kernel, width });
        }
        // the portfolio object arrived with JSON v5 — older files load
        // with none; a malformed entry is corrupt, not legacy
        if let Some(pj) = j.get("portfolio") {
            store.portfolio = Some(PortfolioModel::from_json(pj)?);
        }
        Ok(store)
    }

    /// Persist as pretty JSON (creates parent directories).
    pub fn save(&self, path: &Path) -> Result<(), String> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("wisdom: cannot create {}: {e}", dir.display()))?;
        }
        std::fs::write(path, self.to_json().to_pretty())
            .map_err(|e| format!("wisdom: cannot write {}: {e}", path.display()))
    }

    /// Load a previously [`save`](WisdomStore::save)d store.
    pub fn load(path: &Path) -> Result<WisdomStore, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("wisdom: cannot read {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;

    fn demo_record() -> WisdomRecord {
        let mut surface =
            crate::coordinator::fpm::SpeedFunction::new("native-group1", vec![8, 16], vec![16]);
        surface.set(8, 16, 123.5);
        WisdomRecord {
            engine: EngineId::Native,
            n: 16,
            p: 2,
            t: 1,
            eps: 0.05,
            plan: PlannedTransform {
                n: 16,
                d: vec![10, 6],
                pads: vec![
                    PadDecision { n_padded: 16, t_unpadded: 1.5, t_padded: 1.5 },
                    PadDecision { n_padded: 24, t_unpadded: 2.0, t_padded: 1.25 },
                ],
                algorithm: Algorithm::Hpopta,
                makespan: 0.125,
                kind: TransformKind::C2c,
            },
            predicted_cost_s: 0.01,
            factors: vec![2, 2, 2, 2],
            fpms: vec![surface],
            kernel_gen: crate::dft::radix::kernel_generation().to_string(),
        }
    }

    #[test]
    fn record_json_roundtrip() {
        let rec = demo_record();
        let j = Json::parse(&rec.to_json().to_string()).unwrap();
        let back = WisdomRecord::from_json(&j).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn kind_keyed_records_coexist_and_roundtrip() {
        // same (engine, n, p), different kind: two separate artifacts
        let c2c = demo_record();
        let mut r2c = demo_record();
        r2c.plan = r2c.plan.with_kind(TransformKind::R2c);
        r2c.plan.d = vec![12, 4]; // real plane partitions differ
        let mut store = WisdomStore::new();
        store.insert(c2c.clone());
        store.insert(r2c.clone());
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(EngineId::Native, 16, 2).unwrap().plan.d, c2c.plan.d);
        assert_eq!(
            store.get_kind(EngineId::Native, 16, 2, TransformKind::R2c).unwrap().plan.d,
            r2c.plan.d
        );
        // c2r shares the r2c plane
        assert_eq!(
            store.get_kind(EngineId::Native, 16, 2, TransformKind::C2r).unwrap().plan.d,
            r2c.plan.d
        );
        // both survive persistence with their kinds
        let j = Json::parse(&store.to_json().to_string()).unwrap();
        let back = WisdomStore::from_json(&j).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(
            back.get_kind(EngineId::Native, 16, 2, TransformKind::R2c).unwrap().kind(),
            TransformKind::R2c
        );
    }

    #[test]
    fn v2_records_load_as_c2c() {
        // strip the kind field — a version-2 file
        let mut j = demo_record().to_json();
        if let Json::Obj(fields) = &mut j {
            fields.retain(|(k, _)| k != "kind");
        }
        let back = WisdomRecord::from_json(&j).unwrap();
        assert_eq!(back.kind(), TransformKind::C2c);
        // corrupt kind values are rejected, not defaulted
        let bad = demo_record().to_json().set("kind", "c2z");
        assert!(WisdomRecord::from_json(&bad).is_err());
    }

    #[test]
    fn kernel_generation_mismatch_invalidates_native_records() {
        let mut store = WisdomStore::new();
        // current generation: hits
        store.insert(demo_record());
        assert!(store.get(EngineId::Native, 16, 2).is_some());
        // a record measured against a retired kernel: misses (forces a
        // re-measure so FPM surfaces track the installed kernel)
        let mut stale = demo_record();
        stale.kernel_gen = "stockham-v1-scalar".to_string();
        store.insert(stale.clone());
        assert!(store.get(EngineId::Native, 16, 2).is_none());
        // legacy untagged records stay valid (pre-tag files upgrade
        // without a cold-planning storm)
        let mut legacy = demo_record();
        legacy.kernel_gen = String::new();
        store.insert(legacy);
        assert!(store.get(EngineId::Native, 16, 2).is_some());
        // non-native engines never carry kernel staleness
        stale.engine = EngineId::Sim(Package::Mkl);
        store.insert(stale);
        assert!(store.get(EngineId::Sim(Package::Mkl), 16, 2).is_some());
        // the tag round-trips through JSON
        let rec = demo_record();
        let j = Json::parse(&rec.to_json().to_string()).unwrap();
        assert_eq!(WisdomRecord::from_json(&j).unwrap().kernel_gen, rec.kernel_gen);
    }

    #[test]
    fn fma_generation_splits_wisdom_staleness() {
        // the FMA kernel generation is its own staleness domain: a
        // native record measured under the *other* generation (FMA off
        // vs on) must re-measure, while a record from the installed
        // generation stays warm — including across a JSON persist/load
        // roundtrip, the restart path that motivates the tag
        let cur = crate::dft::radix::kernel_generation();
        let other = if crate::dft::radix::fma_active() {
            "stockham-v2-codelet+avx2"
        } else {
            "stockham-v2-codelet+avx2+fma"
        };
        assert_ne!(cur, other);
        let mut store = WisdomStore::new();
        let mut cross = demo_record();
        cross.kernel_gen = other.to_string();
        store.insert(cross);
        assert!(
            store.get(EngineId::Native, 16, 2).is_none(),
            "record from the other FMA generation must force a re-measure"
        );
        let warm = demo_record(); // tagged with the installed generation
        let j = Json::parse(&warm.to_json().to_string()).unwrap();
        let back = WisdomRecord::from_json(&j).unwrap();
        assert_eq!(back.kernel_gen, cur);
        store.insert(back);
        assert!(
            store.get(EngineId::Native, 16, 2).is_some(),
            "same-generation record must stay warm after reload"
        );
    }

    #[test]
    fn tile_widths_roundtrip_and_go_stale_with_kernel_generation() {
        let mut store = WisdomStore::new();
        store.set_tile(384, TransformKind::C2c, 4);
        store.set_tile(384, TransformKind::R2c, 2);
        assert_eq!(store.tile_width(384, TransformKind::C2c), Some(4));
        // c2r shares the r2c plane, exactly like plan records
        assert_eq!(store.tile_width(384, TransformKind::C2r), Some(2));
        // out-of-range widths are clamped at insert
        store.set_tile(640, TransformKind::C2c, 64);
        assert_eq!(store.tile_width(640, TransformKind::C2c), Some(8));
        let j = Json::parse(&store.to_json().to_string()).unwrap();
        let back = WisdomStore::from_json(&j).unwrap();
        assert_eq!(back.tile_width(384, TransformKind::C2c), Some(4));
        assert_eq!(back.tile_width(384, TransformKind::R2c), Some(2));
        // a width timed against a retired kernel generation misses (the
        // kernel whose port pressure it was tuned for no longer exists)
        let mut stale = back.clone();
        stale.tiles.get_mut(&(384, TransformKind::C2c)).unwrap().kernel =
            "stockham-v1-scalar".to_string();
        assert_eq!(stale.tile_width(384, TransformKind::C2c), None);
        // ...while the entry itself survives until a re-measure re-stamps
        assert_eq!(stale.tiles().count(), 3);
        // clearing drops the entry entirely (memory-drift invalidation)
        let mut cleared = back;
        assert!(cleared.clear_tile(384, TransformKind::C2c).is_some());
        assert_eq!(cleared.tile_width(384, TransformKind::C2c), None);
        assert_eq!(cleared.tiles().count(), 2);
    }

    #[test]
    fn v3_files_load_with_no_tiles_and_artifact_is_stamped_v5() {
        let mut store = WisdomStore::new();
        store.insert(demo_record());
        store.set_tile(16, TransformKind::C2c, 4);
        // strip the tiles array and re-stamp — a version-3 file
        let mut j = store.to_json();
        if let Json::Obj(fields) = &mut j {
            fields.retain(|(k, _)| k != "tiles");
        }
        let j = j.set("version", 3i64);
        let back = WisdomStore::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.len(), 1);
        assert!(back.tiles().next().is_none(), "v3 files carry no measured widths");
        assert_eq!(back.tile_width(16, TransformKind::C2c), None);
        // corrupt tile entries are rejected, not defaulted
        let bad = WisdomStore::new()
            .to_json()
            .set("tiles", Json::Arr(vec![Json::obj().set("n", 8usize)]));
        assert!(WisdomStore::from_json(&bad).is_err());
        let zero = WisdomStore::new().to_json().set(
            "tiles",
            Json::Arr(vec![Json::obj().set("n", 8usize).set("width", 0usize)]),
        );
        assert!(WisdomStore::from_json(&zero).is_err());
        // the artifact itself is stamped v5 in pretty output (the CI
        // upgrade smoke greps for this exact string)
        assert!(store.to_json().to_pretty().contains("\"version\": 5"));
    }

    #[test]
    fn unknown_engine_names_are_rejected_on_load() {
        let bad = demo_record().to_json().set("engine", "cufft");
        let err = WisdomRecord::from_json(&bad).unwrap_err();
        assert!(err.contains("unknown engine"), "{err}");
        // every canonical EngineId spelling (the persisted format since
        // the stringly-typed era) parses back to the same id
        for id in EngineId::ALL {
            let mut rec = demo_record();
            rec.engine = id;
            let j = Json::parse(&rec.to_json().to_string()).unwrap();
            assert_eq!(WisdomRecord::from_json(&j).unwrap().engine, id);
        }
    }

    #[test]
    fn portfolio_persists_and_v4_files_load_without_one() {
        let mkl = EngineId::Sim(Package::Mkl);
        let fftw3 = EngineId::Sim(Package::Fftw3);
        let mut pf = PortfolioModel::new(vec![fftw3, mkl]);
        pf.set_surface(mkl, 512, TransformKind::C2c, 0.002);
        pf.set_surface(fftw3, 512, TransformKind::C2c, 0.004);
        assert_eq!(pf.best_engine(512, TransformKind::C2c, 2), Some(mkl));
        let mut store = WisdomStore::new();
        store.insert(demo_record());
        store.set_portfolio(pf);
        let j = Json::parse(&store.to_json().to_string()).unwrap();
        let back = WisdomStore::from_json(&j).unwrap();
        let bp = back.portfolio().expect("portfolio persisted");
        assert_eq!(bp.surface(mkl, 512, TransformKind::C2c), Some(0.002));
        assert_eq!(bp.pick(512, TransformKind::C2c), Some(mkl));
        // a v4-shaped file (no portfolio object) loads with none
        let mut v4 = store.to_json();
        if let Json::Obj(fields) = &mut v4 {
            fields.retain(|(k, _)| k != "portfolio");
        }
        let v4 = v4.set("version", 4i64);
        let back4 = WisdomStore::from_json(&Json::parse(&v4.to_string()).unwrap()).unwrap();
        assert!(back4.portfolio().is_none());
        assert_eq!(back4.len(), 1);
        // a corrupt portfolio entry is rejected, not dropped
        let bad = WisdomStore::new()
            .to_json()
            .set("portfolio", Json::obj().set("members", Json::Arr(vec![Json::from("cufft")])));
        assert!(WisdomStore::from_json(&bad).is_err());
    }

    #[test]
    fn nan_makespan_survives_as_nan() {
        let mut rec = demo_record();
        rec.plan.makespan = f64::NAN;
        let j = Json::parse(&rec.to_json().to_string()).unwrap();
        let back = WisdomRecord::from_json(&j).unwrap();
        assert!(back.plan.makespan.is_nan());
    }

    #[test]
    fn store_save_load_roundtrip() {
        let mut store = WisdomStore::new();
        store.insert(demo_record());
        store.insert(WisdomRecord::from_simulator(Package::Mkl, 24_704, true));
        let path = std::env::temp_dir()
            .join(format!("hclfft_wisdom_test_{}/w.json", std::process::id()));
        store.save(&path).unwrap();
        let back = WisdomStore::load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(
            back.get(EngineId::Native, 16, 2).unwrap(),
            store.get(EngineId::Native, 16, 2).unwrap()
        );
        let sim = back.get(EngineId::Sim(Package::Mkl), 24_704, 2).unwrap();
        assert_eq!(sim.plan.d.iter().sum::<usize>(), 24_704);
        assert!(sim.predicted_cost_s > 0.0);
    }

    #[test]
    fn store_rejects_corrupt_records() {
        let j = Json::parse(r#"{"records":[{"engine":"native","n":8}]}"#).unwrap();
        assert!(WisdomStore::from_json(&j).is_err());
        // d not summing to n
        let mut rec = demo_record().to_json();
        rec = rec.set("d", vec![1usize, 2]);
        assert!(WisdomRecord::from_json(&rec).is_err());
    }

    #[test]
    fn load_rejects_out_of_range_pads() {
        // pad below n — would otherwise panic a worker at execution time
        let below = demo_record().to_json().set(
            "pads",
            Json::Arr(vec![
                Json::obj().set("n_padded", 8usize),
                Json::obj().set("n_padded", 16usize),
            ]),
        );
        let err = WisdomRecord::from_json(&below).unwrap_err();
        assert!(err.contains("pad length"), "{err}");
        // pad absurdly above n — would demand a huge work buffer
        let above = demo_record().to_json().set(
            "pads",
            Json::Arr(vec![
                Json::obj().set("n_padded", 16usize),
                Json::obj().set("n_padded", usize::MAX / 2),
            ]),
        );
        assert!(WisdomRecord::from_json(&above).is_err());
    }

    #[test]
    fn measured_surfaces_survive_persistence() {
        let rec = demo_record();
        let back =
            WisdomRecord::from_json(&Json::parse(&rec.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.fpms, rec.fpms);
        assert_eq!(back.fpms[0].get(8, 16), Some(123.5));
        // records without the field (older files) load with no surfaces
        let mut legacy = rec.to_json();
        legacy = legacy.set("fpms", Json::Arr(Vec::new()));
        assert!(WisdomRecord::from_json(&legacy).unwrap().fpms.is_empty());
    }

    #[test]
    fn model_deltas_and_drift_log_persist() {
        use crate::model::DriftPolicy;
        let mut store = WisdomStore::new();
        let mut m = OnlineModel::new("sim-mkl", DriftPolicy::default());
        for _ in 0..8 {
            m.observe(128, 64, 0.01);
        }
        for _ in 0..4 {
            m.observe(128, 64, 0.05); // 5x regime shift -> one drift event
        }
        assert_eq!(m.drift_events().len(), 1);
        store.set_model("sim-mkl", m.clone());
        store.insert(demo_record());
        let path = std::env::temp_dir()
            .join(format!("hclfft_wisdom_model_{}/w.json", std::process::id()));
        store.save(&path).unwrap();
        let back = WisdomStore::load(&path).unwrap();
        let back_m = back.model("sim-mkl").expect("model state persisted");
        assert_eq!(back_m.observations(), m.observations());
        assert_eq!(back_m.drift_events(), m.drift_events());
        assert_eq!(back_m.len(), 1);
        // version-1 files (no models field) still load
        let v1 = Json::parse(r#"{"version":1,"records":[]}"#).unwrap();
        assert!(WisdomStore::from_json(&v1).unwrap().models().next().is_none());
    }

    #[test]
    fn from_model_replans_against_scaled_sections() {
        use crate::model::{DriftPolicy, SimModel};
        use std::sync::Arc;
        let pkg = Package::Mkl;
        let cfg = pkg.best_groups();
        let base = Arc::new(SimModel::paper_best(pkg));
        let n = 8_064;
        let mut m = OnlineModel::new("sim-mkl", DriftPolicy::default()).with_base(base.clone());
        // machine observed 2x slower than the base at the service's
        // whole-request point
        let base_t = base.predict_time(2 * n, n).unwrap();
        for _ in 0..6 {
            m.observe(2 * n, n, base_t * 2.0);
        }
        let rec = WisdomRecord::from_model(
            EngineId::Sim(Package::Mkl),
            &m,
            n,
            cfg.p,
            cfg.t,
            crate::simulator::vexec::EPS_IDENTICAL,
            None,
            crate::simulator::vexec::PAD_WINDOW,
        );
        assert_eq!(rec.plan.d.iter().sum::<usize>(), n);
        // predicted cost comes from the refined estimate (2x the base)
        assert!((rec.predicted_cost_s - base_t * 2.0).abs() < 1e-12);
    }

    #[test]
    fn measurement_planning_small_n_is_consistent() {
        let cfg = PlanningConfig {
            groups: 2,
            threads_per_group: 1,
            rep_scale: 10_000,
            ..PlanningConfig::default()
        };
        let rec = WisdomRecord::from_measurement(EngineId::Native, &NativeEngine, 32, &cfg);
        assert_eq!(rec.plan.d.iter().sum::<usize>(), 32);
        assert_eq!(rec.plan.d.len(), 2);
        assert!(!rec.plan.is_padded(), "pad_cost None must not pad");
        assert!(rec.predicted_cost_s > 0.0);
        rec.warm_plan_cache();
    }

    #[test]
    fn factor_schedule_round_trips_and_is_derived_on_load() {
        let rec = demo_record();
        let j = Json::parse(&rec.to_json().to_string()).unwrap();
        let back = WisdomRecord::from_json(&j).unwrap();
        assert_eq!(back.factors, vec![2, 2, 2, 2]);
        // the persisted field is informational: a stale/hand-edited
        // value is replaced by the schedule derived from n, and legacy
        // files without the field load identically
        let stale = rec.to_json().set("factors", Json::Arr(Vec::new()));
        assert_eq!(WisdomRecord::from_json(&stale).unwrap().factors, vec![2, 2, 2, 2]);
        // a non-smooth n (24704 = 128·193) records an empty schedule
        // (Bluestein row kernel)
        let sim = WisdomRecord::from_simulator(Package::Mkl, 24_704, false);
        assert!(sim.factors.is_empty());
    }

    #[test]
    fn simulator_planning_is_deterministic() {
        let a = WisdomRecord::from_simulator(Package::Fftw3, 16_064, false);
        let b = WisdomRecord::from_simulator(Package::Fftw3, 16_064, false);
        assert_eq!(a, b);
        assert!(!a.plan.is_padded());
    }
}
