//! FPM-informed batch scheduling.
//!
//! Requests for the same `(engine, n, direction)` coalesce into one
//! bucket; buckets are dispatched **shortest-predicted-job-first**, where
//! the prediction comes from the *live* performance model — the
//! engine's [`crate::model::OnlineModel`] refined estimate when served
//! traffic has taught it one, the wisdom store's planned cost otherwise
//! (see `Inner::predicted_cost` in [`crate::service`]) — with a
//! **starvation bound**: a
//! bucket whose oldest request has waited longer than the bound is
//! served FIFO ahead of any cheaper bucket, so large transforms cannot
//! be postponed forever by a stream of small ones.
//!
//! The queue is deliberately pure over an abstract clock (`now_s`): the
//! service feeds it wall-clock seconds, unit tests and the virtual-time
//! path feed deterministic timestamps — scheduling behaviour is testable
//! at paper-scale sizes without executing a single FFT.

use crate::coordinator::engine::EngineId;
use crate::dft::fft::Direction;
use crate::dft::real::TransformKind;

/// What coalesces: same engine, same size, same direction, same
/// transform kind (r2c batches run the real executor — mixing them
/// with c2c work would force the slower path on everyone).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct BatchKey {
    /// the engine that will execute the bucket — for portfolio requests
    /// this is the *resolved member*, never [`EngineId::Portfolio`]:
    /// resolution happens at admission, before bucketing
    pub engine: EngineId,
    pub n: usize,
    pub forward: bool,
    pub kind: TransformKind,
}

impl BatchKey {
    pub fn new(engine: EngineId, n: usize, dir: Direction) -> BatchKey {
        BatchKey::new_kind(engine, n, dir, TransformKind::C2c)
    }

    pub fn new_kind(engine: EngineId, n: usize, dir: Direction, kind: TransformKind) -> BatchKey {
        BatchKey { engine, n, forward: dir == Direction::Forward, kind }
    }

    pub fn direction(&self) -> Direction {
        if self.forward {
            Direction::Forward
        } else {
            Direction::Inverse
        }
    }
}

struct Bucket<T> {
    key: BatchKey,
    /// predicted per-request seconds (the SPJF weight)
    cost_s: f64,
    /// FIFO within the bucket
    entries: Vec<(T, f64)>,
    /// enqueue time of the oldest entry
    oldest_s: f64,
    /// tie-break: arrival order of the bucket itself
    seq: u64,
}

/// A popped batch ready for execution.
pub struct Batch<T> {
    pub key: BatchKey,
    /// payloads with their enqueue timestamps, FIFO order
    pub entries: Vec<(T, f64)>,
    pub cost_s: f64,
}

/// The size-bucketed priority queue.
pub struct BatchQueue<T> {
    buckets: Vec<Bucket<T>>,
    next_seq: u64,
    len: usize,
}

impl<T> Default for BatchQueue<T> {
    fn default() -> Self {
        BatchQueue { buckets: Vec::new(), next_seq: 0, len: 0 }
    }
}

impl<T> BatchQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queued request count (all buckets).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Model-priced seconds of queued work: Σ over buckets of
    /// entries × predicted per-request cost. The serve router's
    /// backpressure signal — what a fresh arrival would wait behind
    /// (batching speedups make it an upper bound).
    pub fn backlog_s(&self) -> f64 {
        self.buckets.iter().map(|b| b.entries.len() as f64 * b.cost_s).sum()
    }

    /// Enqueue one request with its predicted per-request cost.
    pub fn push(&mut self, key: BatchKey, cost_s: f64, payload: T, now_s: f64) {
        self.len += 1;
        if let Some(b) = self.buckets.iter_mut().find(|b| b.key == key) {
            // keep the freshest cost estimate (wisdom may have landed
            // between submissions)
            b.cost_s = cost_s;
            b.entries.push((payload, now_s));
            return;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.buckets.push(Bucket {
            key,
            cost_s,
            entries: vec![(payload, now_s)],
            oldest_s: now_s,
            seq,
        });
    }

    /// Dispatch decision: any bucket older than `starvation_bound_s`
    /// goes first (oldest bucket wins among the starved); otherwise the
    /// cheapest predicted bucket wins (ties: older bucket). Up to
    /// `max_batch` entries leave FIFO; a partially drained bucket keeps
    /// its place with an updated oldest timestamp.
    pub fn pop(&mut self, now_s: f64, starvation_bound_s: f64, max_batch: usize) -> Option<Batch<T>> {
        if self.buckets.is_empty() {
            return None;
        }
        let starved: Vec<usize> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| now_s - b.oldest_s >= starvation_bound_s)
            .map(|(i, _)| i)
            .collect();
        let idx = if !starved.is_empty() {
            starved
                .into_iter()
                .min_by(|&a, &b| {
                    let (ba, bb) = (&self.buckets[a], &self.buckets[b]);
                    ba.oldest_s
                        .partial_cmp(&bb.oldest_s)
                        .unwrap()
                        .then(ba.seq.cmp(&bb.seq))
                })
                .unwrap()
        } else {
            (0..self.buckets.len())
                .min_by(|&a, &b| {
                    let (ba, bb) = (&self.buckets[a], &self.buckets[b]);
                    ba.cost_s.partial_cmp(&bb.cost_s).unwrap().then(ba.seq.cmp(&bb.seq))
                })
                .unwrap()
        };
        let take = self.buckets[idx].entries.len().min(max_batch.max(1));
        let b = &mut self.buckets[idx];
        let entries: Vec<(T, f64)> = b.entries.drain(..take).collect();
        self.len -= entries.len();
        let batch = Batch { key: b.key, entries, cost_s: b.cost_s };
        if self.buckets[idx].entries.is_empty() {
            self.buckets.swap_remove(idx);
        } else {
            self.buckets[idx].oldest_s = self.buckets[idx].entries[0].1;
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: usize) -> BatchKey {
        BatchKey::new(EngineId::Native, n, Direction::Forward)
    }

    #[test]
    fn coalesces_same_key() {
        let mut q: BatchQueue<u32> = BatchQueue::new();
        q.push(key(64), 0.1, 1, 0.0);
        q.push(key(64), 0.1, 2, 0.1);
        q.push(key(128), 0.2, 3, 0.2);
        assert_eq!(q.len(), 3);
        let b = q.pop(0.3, f64::INFINITY, 8).unwrap();
        assert_eq!(b.key, key(64));
        assert_eq!(b.entries.iter().map(|e| e.0).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn spjf_orders_by_predicted_cost() {
        let mut q: BatchQueue<&str> = BatchQueue::new();
        q.push(key(24_704), 10.0, "big", 0.0);
        q.push(key(8_064), 1.0, "small", 0.1);
        q.push(key(16_064), 5.0, "mid", 0.2);
        let order: Vec<&str> = std::iter::from_fn(|| {
            q.pop(0.3, f64::INFINITY, 8).map(|b| b.entries[0].0)
        })
        .collect();
        assert_eq!(order, vec!["small", "mid", "big"]);
    }

    #[test]
    fn starvation_bound_restores_fifo() {
        let mut q: BatchQueue<&str> = BatchQueue::new();
        q.push(key(24_704), 10.0, "big", 0.0);
        q.push(key(8_064), 1.0, "small", 0.1);
        // bound 0: everything counts as starved => FIFO
        let b = q.pop(0.2, 0.0, 8).unwrap();
        assert_eq!(b.entries[0].0, "big");
    }

    #[test]
    fn starved_bucket_preempts_cheaper_work() {
        let mut q: BatchQueue<&str> = BatchQueue::new();
        q.push(key(24_704), 10.0, "big", 0.0);
        q.push(key(8_064), 1.0, "small", 5.0);
        // at t=6 the big bucket has waited 6s >= bound 3s => it goes
        // first despite the cheaper small bucket
        let b = q.pop(6.0, 3.0, 8).unwrap();
        assert_eq!(b.entries[0].0, "big");
        // the small bucket (waited 1s) follows
        let b2 = q.pop(6.0, 3.0, 8).unwrap();
        assert_eq!(b2.entries[0].0, "small");
    }

    #[test]
    fn max_batch_splits_bucket_fifo() {
        let mut q: BatchQueue<u32> = BatchQueue::new();
        for i in 0..5 {
            q.push(key(64), 0.1, i, i as f64);
        }
        let b = q.pop(10.0, f64::INFINITY, 3).unwrap();
        assert_eq!(b.entries.iter().map(|e| e.0).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
        let b2 = q.pop(10.0, f64::INFINITY, 3).unwrap();
        assert_eq!(b2.entries.iter().map(|e| e.0).collect::<Vec<_>>(), vec![3, 4]);
        assert!(q.pop(10.0, f64::INFINITY, 3).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn backlog_prices_queued_work() {
        let mut q: BatchQueue<u32> = BatchQueue::new();
        assert_eq!(q.backlog_s(), 0.0);
        q.push(key(64), 0.1, 1, 0.0);
        q.push(key(64), 0.1, 2, 0.1);
        q.push(key(128), 0.5, 3, 0.2);
        assert!((q.backlog_s() - 0.7).abs() < 1e-12);
        q.pop(0.3, f64::INFINITY, 8).unwrap();
        assert!((q.backlog_s() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn direction_separates_buckets() {
        let mut q: BatchQueue<u32> = BatchQueue::new();
        q.push(BatchKey::new(EngineId::Native, 64, Direction::Forward), 0.1, 1, 0.0);
        q.push(BatchKey::new(EngineId::Native, 64, Direction::Inverse), 0.1, 2, 0.0);
        let b = q.pop(0.0, f64::INFINITY, 8).unwrap();
        assert_eq!(b.entries.len(), 1);
        assert_eq!(b.key.direction(), Direction::Forward);
        assert_eq!(q.pop(0.0, f64::INFINITY, 8).unwrap().key.direction(), Direction::Inverse);
    }

    #[test]
    fn kind_separates_buckets() {
        // an r2c request must never coalesce with a c2c request of the
        // same (engine, n, direction) — they run different executors
        let mut q: BatchQueue<u32> = BatchQueue::new();
        q.push(BatchKey::new(EngineId::Native, 64, Direction::Forward), 0.1, 1, 0.0);
        q.push(
            BatchKey::new_kind(EngineId::Native, 64, Direction::Forward, TransformKind::R2c),
            0.1,
            2,
            0.0,
        );
        let b = q.pop(0.0, f64::INFINITY, 8).unwrap();
        assert_eq!(b.entries.len(), 1);
        let b2 = q.pop(0.0, f64::INFINITY, 8).unwrap();
        assert_eq!(b2.entries.len(), 1);
        assert_ne!(b.key.kind, b2.key.kind);
    }
}
