//! PJRT runtime — loads and executes the AOT JAX/Pallas artifacts.
//!
//! The AOT bridge: `python/compile/aot.py` lowers the L2 model (calling
//! the L1 Pallas kernels) to HLO **text**; this module loads it with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client,
//! and executes it from the L3 hot path. Python never runs at request
//! time.
//!
//! ## Feature gating
//!
//! The actual XLA execution lives behind the `pjrt` cargo feature
//! because the `xla` bindings crate is not in the offline vendor set.
//! Without the feature, an API-identical stub still parses manifests and
//! reports shape support, but every execution returns a clear
//! `EngineError::Runtime` — callers (CLI, figures, tests) detect this
//! via [`pjrt_available`] and skip with a printed notice.
//!
//! ## Threading model (feature `pjrt`)
//!
//! The `xla` crate's `PjRtClient` holds a non-atomic `Rc`, and executing
//! clones it into output buffers — so **all** PJRT object creation, use
//! and destruction is serialized behind one mutex (`PjrtCore`). On this
//! single-core testbed serialization costs nothing; on a multi-core box
//! the PJRT CPU client parallelizes internally anyway. Only plain
//! `Vec<f32>` data crosses the lock boundary.

pub mod manifest;

use std::path::Path;

use crate::coordinator::engine::{EngineError, RowFftEngine};
use crate::dft::fft::Direction;
pub use manifest::{Kind, Manifest};

/// True when this build can actually execute PJRT artifacts.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

#[cfg(feature = "pjrt")]
mod xla_backend {
    use std::collections::HashMap;
    use std::sync::Mutex;

    use super::manifest::{self, Kind, Manifest};
    use super::{Direction, EngineError, Path};

    /// The serialized PJRT state: client + compiled-executable cache.
    struct PjrtCore {
        client: xla::PjRtClient,
        cache: HashMap<(Kind, usize, usize), xla::PjRtLoadedExecutable>,
        manifest: Manifest,
    }

    // SAFETY: `PjrtCore` is only ever accessed through `PjrtRuntime.inner`
    // (a Mutex). PJRT objects are created, executed and dropped strictly
    // under that lock, so the non-atomic Rc refcounts inside the xla crate
    // wrappers are never touched concurrently; the TFRT CPU client itself
    // is thread-safe. The wrapper types are merely moved across threads,
    // which the underlying C++ objects permit.
    unsafe impl Send for PjrtCore {}

    /// The runtime handle (cheap to share by reference across threads).
    pub struct PjrtRuntime {
        inner: Mutex<PjrtCore>,
    }

    impl PjrtRuntime {
        /// Create a CPU-PJRT runtime over an artifacts directory.
        pub fn load(artifacts_dir: &Path) -> Result<PjrtRuntime, EngineError> {
            let manifest = Manifest::load(artifacts_dir).map_err(EngineError::Runtime)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| EngineError::Runtime(format!("PJRT client: {e}")))?;
            Ok(PjrtRuntime {
                inner: Mutex::new(PjrtCore { client, cache: HashMap::new(), manifest }),
            })
        }

        /// Row lengths executable by this runtime (the artifact grid).
        pub fn supported_lengths(&self) -> Vec<usize> {
            self.inner.lock().unwrap().manifest.lengths(Kind::RowFft)
        }

        /// Number of compiled executables currently cached (perf counter).
        pub fn cached_executables(&self) -> usize {
            self.inner.lock().unwrap().cache.len()
        }

        /// Execute `rows` row-FFTs of length `n` over f32 planes, tiling
        /// the batch greedily onto the artifact chunk grid.
        pub fn row_ffts_f32(
            &self,
            re: &mut [f32],
            im: &mut [f32],
            rows: usize,
            n: usize,
            dir: Direction,
        ) -> Result<(), EngineError> {
            let kind = match dir {
                Direction::Forward => Kind::RowFft,
                Direction::Inverse => Kind::RowIfft,
            };
            let mut core = self.inner.lock().unwrap();
            let chunks = core.manifest.chunks_for(kind, n);
            if chunks.is_empty() {
                return Err(EngineError::unsupported_length(n, "pjrt"));
            }
            let plan = manifest::tile_rows(rows, &chunks).map_err(EngineError::Runtime)?;
            let mut row = 0usize;
            for chunk in plan {
                let span = row * n..(row + chunk) * n;
                core.execute_chunk(kind, chunk, n, &mut re[span.clone()], &mut im[span])?;
                row += chunk;
            }
            Ok(())
        }

        /// Execute the whole-2D-DFT artifact (`full2d_<n>`), if present.
        pub fn full2d_f32(
            &self,
            re: &mut [f32],
            im: &mut [f32],
            n: usize,
        ) -> Result<(), EngineError> {
            let mut core = self.inner.lock().unwrap();
            if core.manifest.find(Kind::Full2d, n, n).is_none() {
                return Err(EngineError::unsupported_length(n, "pjrt-full2d"));
            }
            core.execute_chunk(Kind::Full2d, n, n, re, im)
        }
    }

    impl PjrtCore {
        fn executable(
            &mut self,
            kind: Kind,
            rows: usize,
            n: usize,
        ) -> Result<&xla::PjRtLoadedExecutable, EngineError> {
            if !self.cache.contains_key(&(kind, rows, n)) {
                let entry = self
                    .manifest
                    .find(kind, rows, n)
                    .ok_or_else(|| EngineError::unsupported_length(n, format!("pjrt {rows}x{n}")))?;
                let proto = xla::HloModuleProto::from_text_file(
                    entry.path.to_str().ok_or_else(|| EngineError::Runtime("bad path".into()))?,
                )
                .map_err(|e| {
                    EngineError::Runtime(format!("HLO parse {}: {e}", entry.path.display()))
                })?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| EngineError::Runtime(format!("compile {rows}x{n}: {e}")))?;
                self.cache.insert((kind, rows, n), exe);
            }
            Ok(&self.cache[&(kind, rows, n)])
        }

        /// Run one (rows, n) executable over the given planes, in place.
        ///
        /// Perf (EXPERIMENTS.md §Perf): inputs go through
        /// `buffer_from_host_buffer` (one host->device transfer; the naive
        /// `Literal::vec1(..).reshape(..)` path copies twice before the
        /// transfer), and outputs come back via `Literal::copy_raw_to`
        /// straight into the caller's slices (the `to_vec` path allocates
        /// and copies an extra time per plane).
        fn execute_chunk(
            &mut self,
            kind: Kind,
            rows: usize,
            n: usize,
            re: &mut [f32],
            im: &mut [f32],
        ) -> Result<(), EngineError> {
            debug_assert_eq!(re.len(), rows * n);
            let rt = |e: xla::Error| EngineError::Runtime(e.to_string());
            self.executable(kind, rows, n)?; // ensure compiled (fills cache)
            let exe = &self.cache[&(kind, rows, n)];
            let dims = [rows, n];
            let b_re = self.client.buffer_from_host_buffer(re, &dims, None).map_err(rt)?;
            let b_im = self.client.buffer_from_host_buffer(im, &dims, None).map_err(rt)?;
            let result = exe.execute_b(&[&b_re, &b_im]).map_err(rt)?;
            let out = result[0][0].to_literal_sync().map_err(rt)?;
            // lowered with return_tuple=True: (re, im)
            let (out_re, out_im) = out.to_tuple2().map_err(rt)?;
            out_re.copy_raw_to(re).map_err(rt)?;
            out_im.copy_raw_to(im).map_err(rt)?;
            Ok(())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_backend {
    use std::sync::Mutex;

    use super::manifest::{Kind, Manifest};
    use super::{Direction, EngineError, Path};

    /// API-identical stand-in for the XLA-backed runtime: manifest
    /// handling (and therefore shape validation and every manifest error
    /// path) is real, execution reports that the build lacks the `pjrt`
    /// feature.
    pub struct PjrtRuntime {
        inner: Mutex<Manifest>,
    }

    fn not_compiled() -> EngineError {
        EngineError::Runtime(
            "hclfft was built without the `pjrt` feature; \
             rebuild with `--features pjrt` (requires the `xla` crate) to execute artifacts"
                .to_string(),
        )
    }

    impl PjrtRuntime {
        /// Load `<dir>/manifest.tsv`; execution is unavailable in this
        /// build, so only manifest-level errors surface here.
        pub fn load(artifacts_dir: &Path) -> Result<PjrtRuntime, EngineError> {
            let manifest = Manifest::load(artifacts_dir).map_err(EngineError::Runtime)?;
            Ok(PjrtRuntime { inner: Mutex::new(manifest) })
        }

        /// Row lengths the manifest declares (the artifact grid).
        pub fn supported_lengths(&self) -> Vec<usize> {
            self.inner.lock().unwrap().lengths(Kind::RowFft)
        }

        /// Always 0 — nothing can compile without the feature.
        pub fn cached_executables(&self) -> usize {
            0
        }

        pub fn row_ffts_f32(
            &self,
            _re: &mut [f32],
            _im: &mut [f32],
            _rows: usize,
            n: usize,
            dir: Direction,
        ) -> Result<(), EngineError> {
            let kind = match dir {
                Direction::Forward => Kind::RowFft,
                Direction::Inverse => Kind::RowIfft,
            };
            if self.inner.lock().unwrap().chunks_for(kind, n).is_empty() {
                return Err(EngineError::unsupported_length(n, "pjrt"));
            }
            Err(not_compiled())
        }

        pub fn full2d_f32(
            &self,
            _re: &mut [f32],
            _im: &mut [f32],
            n: usize,
        ) -> Result<(), EngineError> {
            if self.inner.lock().unwrap().find(Kind::Full2d, n, n).is_none() {
                return Err(EngineError::unsupported_length(n, "pjrt-full2d"));
            }
            Err(not_compiled())
        }
    }
}

#[cfg(feature = "pjrt")]
pub use xla_backend::PjrtRuntime;
#[cfg(not(feature = "pjrt"))]
pub use stub_backend::PjrtRuntime;

/// `RowFftEngine` over the PJRT runtime: f64 planes are converted to f32
/// at the boundary (the artifacts are f32 — the TPU-friendly dtype).
pub struct PjrtRowFftEngine {
    pub runtime: PjrtRuntime,
}

impl PjrtRowFftEngine {
    pub fn load(artifacts_dir: &Path) -> Result<Self, EngineError> {
        Ok(PjrtRowFftEngine { runtime: PjrtRuntime::load(artifacts_dir)? })
    }
}

impl RowFftEngine for PjrtRowFftEngine {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn fft_rows(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        rows: usize,
        n: usize,
        dir: Direction,
        _threads: usize, // PJRT CPU client owns its own thread pool
    ) -> Result<(), EngineError> {
        let mut re32: Vec<f32> = re.iter().map(|&v| v as f32).collect();
        let mut im32: Vec<f32> = im.iter().map(|&v| v as f32).collect();
        self.runtime.row_ffts_f32(&mut re32, &mut im32, rows, n, dir)?;
        for (dst, src) in re.iter_mut().zip(&re32) {
            *dst = *src as f64;
        }
        for (dst, src) in im.iter_mut().zip(&im32) {
            *dst = *src as f64;
        }
        Ok(())
    }

    fn supported_lengths(&self) -> Option<Vec<usize>> {
        Some(self.runtime.supported_lengths())
    }
}
