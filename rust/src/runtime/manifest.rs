//! Artifact manifest parsing (`artifacts/manifest.tsv`).
//!
//! Written by `python/compile/aot.py`; four tab-separated columns:
//! `kind  rows  n  file`. TSV instead of JSON because the offline vendor
//! set has no serde and the schema is a flat table.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// Artifact kinds the AOT grid produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Kind {
    RowFft,
    RowIfft,
    Full2d,
}

impl Kind {
    pub fn parse(s: &str) -> Option<Kind> {
        match s {
            "row_fft" => Some(Kind::RowFft),
            "row_ifft" => Some(Kind::RowIfft),
            "full2d" => Some(Kind::Full2d),
            _ => None,
        }
    }
}

/// One artifact entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    pub kind: Kind,
    pub rows: usize,
    pub n: usize,
    pub path: PathBuf,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`; paths are resolved relative to `dir`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("manifest: cannot read {}: {e}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                return Err(format!("manifest line {}: expected 4 columns", lineno + 1));
            }
            let kind = Kind::parse(cols[0])
                .ok_or_else(|| format!("manifest line {}: unknown kind `{}`", lineno + 1, cols[0]))?;
            let rows: usize = cols[1]
                .parse()
                .map_err(|_| format!("manifest line {}: bad rows", lineno + 1))?;
            let n: usize = cols[2]
                .parse()
                .map_err(|_| format!("manifest line {}: bad n", lineno + 1))?;
            entries.push(Entry { kind, rows, n, path: dir.join(cols[3]) });
        }
        if entries.is_empty() {
            return Err("manifest: no entries".to_string());
        }
        Ok(Manifest { entries })
    }

    /// Row lengths available for a kind (the engine's supported grid).
    pub fn lengths(&self, kind: Kind) -> Vec<usize> {
        let set: BTreeSet<usize> =
            self.entries.iter().filter(|e| e.kind == kind).map(|e| e.n).collect();
        set.into_iter().collect()
    }

    /// Chunk row-counts available for (kind, n), descending (greedy tiling).
    pub fn chunks_for(&self, kind: Kind, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == kind && e.n == n)
            .map(|e| e.rows)
            .collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v.dedup();
        v
    }

    /// Find the artifact for exactly (kind, rows, n).
    pub fn find(&self, kind: Kind, rows: usize, n: usize) -> Option<&Entry> {
        self.entries.iter().find(|e| e.kind == kind && e.rows == rows && e.n == n)
    }
}

/// Greedy decomposition of `rows` into available chunk sizes
/// (descending). Errors if no chunk can cover a remainder (i.e. no
/// 1-row chunk exists and rows isn't expressible).
pub fn tile_rows(rows: usize, chunks_desc: &[usize]) -> Result<Vec<usize>, String> {
    let mut plan = Vec::new();
    let mut left = rows;
    for &c in chunks_desc {
        while left >= c {
            plan.push(c);
            left -= c;
        }
    }
    if left != 0 {
        return Err(format!(
            "cannot tile {rows} rows with chunks {chunks_desc:?} (left {left})"
        ));
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# kind\trows\tn\tfile\n\
        row_fft\t8\t128\trow_fft_8x128.hlo.txt\n\
        row_fft\t1\t128\trow_fft_1x128.hlo.txt\n\
        row_ifft\t8\t128\trow_ifft_8x128.hlo.txt\n\
        full2d\t128\t128\tfull2d_128.hlo.txt\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.lengths(Kind::RowFft), vec![128]);
        assert_eq!(m.chunks_for(Kind::RowFft, 128), vec![8, 1]);
        let e = m.find(Kind::Full2d, 128, 128).unwrap();
        assert_eq!(e.path, Path::new("/tmp/a/full2d_128.hlo.txt"));
        assert!(m.find(Kind::RowFft, 32, 128).is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("row_fft\t8\t128", Path::new("/")).is_err());
        assert!(Manifest::parse("bogus\t8\t128\tx\n", Path::new("/")).is_err());
        assert!(Manifest::parse("", Path::new("/")).is_err());
        assert!(Manifest::parse("row_fft\tx\t128\tf\n", Path::new("/")).is_err());
    }

    #[test]
    fn tiling_greedy() {
        assert_eq!(tile_rows(300, &[128, 32, 8, 1]).unwrap(), vec![128, 128, 32, 8, 1, 1, 1, 1]);
        assert_eq!(tile_rows(0, &[8, 1]).unwrap(), Vec::<usize>::new());
        assert_eq!(tile_rows(7, &[8, 1]).unwrap(), vec![1; 7]);
        assert!(tile_rows(7, &[8, 4]).is_err());
    }
}
