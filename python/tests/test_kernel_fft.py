"""L1 kernel vs oracle — the CORE build-time correctness signal.

The Pallas Stockham kernel is compared against two independent references
(jnp.fft and the naive O(N^2) DFT-matrix oracle), plus algebraic FFT
properties (linearity, impulse, Parseval, roundtrip). Hypothesis sweeps
shapes and seeds.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fft import row_fft, DEFAULT_BLOCK_ROWS
from compile.kernels.ref import dft_rows_naive, fft_rows_ref

RTOL = 2e-4
ATOL = 2e-4


def rand_planes(rng, rows, n):
    return (
        rng.standard_normal((rows, n)).astype(np.float32),
        rng.standard_normal((rows, n)).astype(np.float32),
    )


def tol(n):
    # float32 FFT error grows ~ sqrt(log n); scale tolerance accordingly.
    return dict(rtol=RTOL * math.log2(max(n, 2)), atol=ATOL * math.log2(max(n, 2)))


@pytest.mark.parametrize("rows,n", [(1, 2), (1, 8), (4, 16), (8, 64),
                                    (16, 128), (8, 256), (4, 512), (2, 1024)])
def test_kernel_matches_jnp_fft(rows, n):
    rng = np.random.default_rng(rows * 1000 + n)
    re, im = rand_planes(rng, rows, n)
    kr, ki = row_fft(jnp.asarray(re), jnp.asarray(im))
    rr, ri = fft_rows_ref(re, im)
    np.testing.assert_allclose(kr, rr, **tol(n))
    np.testing.assert_allclose(ki, ri, **tol(n))


@pytest.mark.parametrize("rows,n", [(2, 4), (4, 32), (8, 128)])
def test_kernel_matches_naive_dft(rows, n):
    rng = np.random.default_rng(42 + n)
    re, im = rand_planes(rng, rows, n)
    kr, ki = row_fft(jnp.asarray(re), jnp.asarray(im))
    nr, ni = dft_rows_naive(re, im)
    np.testing.assert_allclose(kr, nr, **tol(n))
    np.testing.assert_allclose(ki, ni, **tol(n))


@pytest.mark.parametrize("rows,n", [(4, 16), (8, 128), (2, 512)])
def test_inverse_roundtrip(rows, n):
    rng = np.random.default_rng(7 + n)
    re, im = rand_planes(rng, rows, n)
    fr, fi = row_fft(jnp.asarray(re), jnp.asarray(im))
    br, bi = row_fft(fr, fi, inverse=True)
    np.testing.assert_allclose(br, re, **tol(n))
    np.testing.assert_allclose(bi, im, **tol(n))


def test_impulse_is_flat_spectrum():
    n = 64
    re = np.zeros((1, n), np.float32)
    im = np.zeros((1, n), np.float32)
    re[0, 0] = 1.0
    kr, ki = row_fft(jnp.asarray(re), jnp.asarray(im))
    np.testing.assert_allclose(kr, np.ones((1, n)), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ki, np.zeros((1, n)), rtol=1e-5, atol=1e-5)


def test_constant_signal_is_delta():
    n = 128
    re = np.ones((2, n), np.float32)
    im = np.zeros((2, n), np.float32)
    kr, ki = row_fft(jnp.asarray(re), jnp.asarray(im))
    expect = np.zeros((2, n), np.float32)
    expect[:, 0] = n
    np.testing.assert_allclose(kr, expect, atol=1e-3)
    np.testing.assert_allclose(ki, np.zeros((2, n)), atol=1e-3)


def test_linearity():
    rng = np.random.default_rng(3)
    re1, im1 = rand_planes(rng, 4, 64)
    re2, im2 = rand_planes(rng, 4, 64)
    a, b = 2.5, -1.25
    f1 = row_fft(jnp.asarray(re1), jnp.asarray(im1))
    f2 = row_fft(jnp.asarray(re2), jnp.asarray(im2))
    fs = row_fft(jnp.asarray(a * re1 + b * re2), jnp.asarray(a * im1 + b * im2))
    np.testing.assert_allclose(fs[0], a * f1[0] + b * f2[0], **tol(64))
    np.testing.assert_allclose(fs[1], a * f1[1] + b * f2[1], **tol(64))


def test_parseval():
    rng = np.random.default_rng(11)
    re, im = rand_planes(rng, 4, 256)
    kr, ki = row_fft(jnp.asarray(re), jnp.asarray(im))
    time_energy = (re**2 + im**2).sum()
    freq_energy = float((np.asarray(kr) ** 2 + np.asarray(ki) ** 2).sum()) / 256
    assert abs(time_energy - freq_energy) / time_energy < 1e-4


def test_rejects_non_power_of_two():
    re = np.zeros((2, 12), np.float32)
    with pytest.raises(ValueError, match="power of two"):
        row_fft(jnp.asarray(re), jnp.asarray(re))


def test_rejects_mismatched_planes():
    re = np.zeros((2, 16), np.float32)
    im = np.zeros((2, 8), np.float32)
    with pytest.raises(ValueError):
        row_fft(jnp.asarray(re), jnp.asarray(im))


def test_rejects_bad_block_rows():
    re = np.zeros((6, 16), np.float32)
    with pytest.raises(ValueError, match="divide"):
        row_fft(jnp.asarray(re), jnp.asarray(re), block_rows=4)


@settings(max_examples=20, deadline=None)
@given(
    rows_pow=st.integers(min_value=0, max_value=4),
    n_pow=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    inverse=st.booleans(),
)
def test_hypothesis_kernel_vs_ref(rows_pow, n_pow, seed, inverse):
    rows, n = 2**rows_pow, 2**n_pow
    rng = np.random.default_rng(seed)
    re, im = rand_planes(rng, rows, n)
    kr, ki = row_fft(jnp.asarray(re), jnp.asarray(im), inverse=inverse)
    rr, ri = fft_rows_ref(re, im, inverse=inverse)
    if inverse:
        rr, ri = rr / n, ri / n  # ref returns unnormalised inverse
    np.testing.assert_allclose(kr, rr, **tol(n))
    np.testing.assert_allclose(ki, ri, **tol(n))


@settings(max_examples=10, deadline=None)
@given(block_pow=st.integers(min_value=0, max_value=4))
def test_hypothesis_block_rows_invariance(block_pow):
    """Result must not depend on the grid blocking."""
    rows, n = 16, 64
    rng = np.random.default_rng(99)
    re, im = rand_planes(rng, rows, n)
    base = row_fft(jnp.asarray(re), jnp.asarray(im), block_rows=rows)
    blocked = row_fft(jnp.asarray(re), jnp.asarray(im), block_rows=2**block_pow)
    np.testing.assert_allclose(base[0], blocked[0], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(base[1], blocked[1], rtol=1e-6, atol=1e-6)
