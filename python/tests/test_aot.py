"""AOT pipeline tests: lowering produces valid HLO text + a sane manifest.

These run the same lowering code path as ``make artifacts`` on a small
grid, and additionally check the HLO is loadable by the *same* text parser
the rust side uses (via xla_client round-trip).
"""

import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def small_grid(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, row_chunks=(1, 8), sizes=(128,),
                         full2d_sizes=(128,), verbose=False)
    return out, manifest


def test_manifest_contents(small_grid):
    out, manifest = small_grid
    kinds = {m[0] for m in manifest}
    assert kinds == {"row_fft", "row_ifft", "full2d"}
    # 2 chunks x 1 size x 2 directions + 1 full2d
    assert len(manifest) == 5
    for kind, rows, n, fname in manifest:
        assert os.path.exists(os.path.join(out, fname))


def test_manifest_tsv_parses(small_grid):
    out, manifest = small_grid
    lines = open(os.path.join(out, "manifest.tsv")).read().strip().splitlines()
    assert lines[0].startswith("#")
    rows = [l.split("\t") for l in lines[1:]]
    assert len(rows) == len(manifest)
    for kind, r, n, fname in rows:
        assert kind in ("row_fft", "row_ifft", "full2d")
        assert int(r) > 0 and int(n) > 0
        assert fname.endswith(".hlo.txt")


def test_hlo_text_is_entry_computation(small_grid):
    out, manifest = small_grid
    for kind, rows, n, fname in manifest:
        text = open(os.path.join(out, fname)).read()
        assert "ENTRY" in text, f"{fname} lacks ENTRY computation"
        assert "f32[" in text


def test_hlo_executes_under_jax(small_grid):
    """Compile the lowered row_fft HLO back and run it — numerics intact."""
    import numpy as np
    import jax.numpy as jnp
    from compile.kernels.ref import fft_rows_ref

    out, manifest = small_grid
    fname = next(m[3] for m in manifest if m[0] == "row_fft" and m[1] == 8)
    # round-trip through the text parser the rust loader uses
    from jax._src.lib import xla_client as xc
    text = open(os.path.join(out, fname)).read()
    # parsing check: the proto must materialise from text
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None

    # numeric check via jax on the original function (the HLO itself is
    # executed on the rust side in rust/tests/runtime_integration.rs)
    rng = np.random.default_rng(0)
    re = rng.standard_normal((8, 128)).astype(np.float32)
    im = rng.standard_normal((8, 128)).astype(np.float32)
    from compile import model
    mr, mi = model.row_fft_stage(jnp.asarray(re), jnp.asarray(im))
    rr, ri = fft_rows_ref(re, im)
    np.testing.assert_allclose(mr, rr, rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(mi, ri, rtol=3e-3, atol=3e-3)
