"""Property tests on the reference oracles themselves (the kernel tests
lean on these oracles, so their own algebra is verified independently)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    dft_matrix,
    dft_rows_naive,
    dft2d_ref,
    fft_rows_ref,
    from_complex,
    to_complex,
)


def test_dft_matrix_is_unitary_up_to_scale():
    n = 16
    w = dft_matrix(n)
    prod = w @ w.conj().T
    np.testing.assert_allclose(prod, n * np.eye(n), atol=1e-9)


def test_dft_matrix_inverse_is_actual_inverse():
    n = 12
    f = dft_matrix(n)
    b = dft_matrix(n, inverse=True)
    np.testing.assert_allclose(f @ b, np.eye(n), atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=32),
    rows=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_naive_matches_jnp_fft_any_length(n, rows, seed):
    # the naive oracle covers arbitrary (non-pow2) lengths
    rng = np.random.default_rng(seed)
    re = rng.standard_normal((rows, n)).astype(np.float32)
    im = rng.standard_normal((rows, n)).astype(np.float32)
    nr, ni = dft_rows_naive(re, im)
    rr, ri = fft_rows_ref(re, im)
    np.testing.assert_allclose(nr, rr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(ni, ri, rtol=1e-3, atol=1e-3)


def test_complex_split_roundtrip():
    rng = np.random.default_rng(0)
    z = rng.standard_normal((3, 5)) + 1j * rng.standard_normal((3, 5))
    z32 = jnp.asarray(z, dtype=jnp.complex64)
    re, im = from_complex(z32)
    back = to_complex(re, im)
    np.testing.assert_allclose(np.asarray(back), np.asarray(z32), rtol=1e-6)


def test_dft2d_ref_separability():
    # fft2 equals row-transform then column-transform
    rng = np.random.default_rng(1)
    re = rng.standard_normal((8, 8)).astype(np.float32)
    im = rng.standard_normal((8, 8)).astype(np.float32)
    rr, ri = dft2d_ref(re, im)
    # manual: rows, transpose, rows, transpose
    ar, ai = fft_rows_ref(re, im)
    ar, ai = np.asarray(ar).T, np.asarray(ai).T
    br, bi = fft_rows_ref(ar, ai)
    br, bi = np.asarray(br).T, np.asarray(bi).T
    np.testing.assert_allclose(rr, br, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ri, bi, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [1, 2, 3])
def test_tiny_sizes(n):
    re = np.ones((1, n), np.float32)
    im = np.zeros((1, n), np.float32)
    nr, ni = dft_rows_naive(re, im)
    assert nr[0, 0] == pytest.approx(n, rel=1e-6)
