"""L2 model tests: full 2D-DFT graph vs jnp.fft.fft2 and shape contracts."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels.ref import dft2d_ref


@pytest.mark.parametrize("n", [8, 64, 128])
def test_dft2d_matches_fft2(n):
    rng = np.random.default_rng(n)
    re = rng.standard_normal((n, n)).astype(np.float32)
    im = rng.standard_normal((n, n)).astype(np.float32)
    mr, mi = model.dft2d(jnp.asarray(re), jnp.asarray(im),
                         block_rows=min(8, n), transpose_block=min(64, n))
    rr, ri = dft2d_ref(re, im)
    # 2D float32 FFT: absolute error scales with n; use a scaled tolerance.
    scale = np.abs(np.asarray(rr)).max() + 1.0
    np.testing.assert_allclose(np.asarray(mr) / scale, np.asarray(rr) / scale, atol=3e-5)
    np.testing.assert_allclose(np.asarray(mi) / scale, np.asarray(ri) / scale, atol=3e-5)


def test_dft2d_rejects_non_square():
    re = jnp.zeros((4, 8), jnp.float32)
    with pytest.raises(ValueError, match="square"):
        model.dft2d(re, re)


def test_row_fft_stage_shapes():
    re = jnp.zeros((8, 64), jnp.float32)
    out = model.row_fft_stage(re, re)
    assert isinstance(out, tuple) and len(out) == 2
    assert out[0].shape == (8, 64) and out[1].shape == (8, 64)
    assert out[0].dtype == jnp.float32


def test_row_fft_stage_row_independence():
    """Each row transforms independently — permuting rows commutes."""
    rng = np.random.default_rng(1)
    re = rng.standard_normal((8, 32)).astype(np.float32)
    im = rng.standard_normal((8, 32)).astype(np.float32)
    perm = rng.permutation(8)
    a = model.row_fft_stage(jnp.asarray(re), jnp.asarray(im), block_rows=8)
    b = model.row_fft_stage(jnp.asarray(re[perm]), jnp.asarray(im[perm]), block_rows=8)
    np.testing.assert_allclose(np.asarray(a[0])[perm], b[0], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a[1])[perm], b[1], rtol=1e-6, atol=1e-6)
