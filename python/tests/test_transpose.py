"""Pallas blocked-transpose kernel tests (paper Appendix A analogue)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.transpose import transpose


@pytest.mark.parametrize("n,block", [(4, 2), (64, 64), (128, 64), (256, 64), (64, 16)])
def test_transpose_matches_numpy(n, block):
    rng = np.random.default_rng(n)
    x = rng.standard_normal((n, n)).astype(np.float32)
    y = transpose(jnp.asarray(x), block=block)
    np.testing.assert_array_equal(np.asarray(y), x.T)


def test_transpose_involution():
    rng = np.random.default_rng(5)
    x = rng.standard_normal((128, 128)).astype(np.float32)
    y = transpose(transpose(jnp.asarray(x)))
    np.testing.assert_array_equal(np.asarray(y), x)


def test_rejects_non_square():
    x = np.zeros((4, 8), np.float32)
    with pytest.raises(ValueError, match="square"):
        transpose(jnp.asarray(x))


def test_rejects_bad_block():
    x = np.zeros((12, 12), np.float32)
    with pytest.raises(ValueError, match="divide"):
        transpose(jnp.asarray(x), block=8)


@settings(max_examples=15, deadline=None)
@given(
    n_pow=st.integers(min_value=1, max_value=8),
    block_pow=st.integers(min_value=0, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_transpose(n_pow, block_pow, seed):
    n = 2**n_pow
    block = 2 ** min(block_pow, n_pow)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, n)).astype(np.float32)
    y = transpose(jnp.asarray(x), block=block)
    np.testing.assert_array_equal(np.asarray(y), x.T)
