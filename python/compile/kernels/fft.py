"""L1 — Pallas batched row-FFT kernel (Stockham radix-2 autosort).

The paper's compute hot spot is "x row 1D-FFTs of length y"
(``1D_ROW_FFTS_LOCAL``, Algorithm 6). This kernel is that routine: it
transforms a block of rows, each a power-of-two length-``n`` complex
signal stored as split float32 re/im planes.

Why Stockham (and not Cooley-Tukey + bit reversal):

* autosorting — no data-dependent permutation, every stage is a dense
  strided reshape + multiply + stack, i.e. exactly the kind of
  gather-free tile op the TPU VPU/MXU likes;
* the (rows_block, n) tile is the natural VMEM block: rows map to the
  sublane/batch axis, the transform axis stays whole in-lane;
* log2(n) stages of O(1) jnp ops keep the traced HLO tiny (important
  because the AOT grid lowers dozens of shapes).

The kernel MUST run with ``interpret=True``: the CPU PJRT plugin used by
the rust runtime cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation).

Hardware adaptation note (paper targets a 2-socket Haswell): the paper
parallelises rows across thread groups; here the grid dimension blocks
rows, so ``grid=(rows/block_rows,)`` plays the role of the OpenMP
section, and the L3 rust coordinator plays the role of the paper's
abstract processors by dispatching row *chunks* to PJRT executables.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default rows-per-grid-step. 8 rows x 4096 cols x 2 planes x 4B = 256 KiB,
# comfortably inside a TPU core's ~16 MiB VMEM together with the stage
# ping-pong buffer; see DESIGN.md §Perf for the sweep.
DEFAULT_BLOCK_ROWS = 8


def _stockham_stages(xr, xi, n: int, inverse: bool):
    """Run log2(n) Stockham radix-2 DIF stages over the last axis.

    State layout: (rows, n_cur, s) where the original index is
    ``q + s * p`` with p in [0, n_cur), q in [0, s). Starts at
    (rows, n, 1); each stage halves n_cur and doubles s; ends at
    (rows, 1, n) holding the transform in natural order.
    """
    rows = xr.shape[0]
    xr = xr.reshape(rows, n, 1)
    xi = xi.reshape(rows, n, 1)
    n_cur, s = n, 1
    sign = 1.0 if inverse else -1.0
    while n_cur > 1:
        m = n_cur // 2
        ar, ai = xr[:, :m, :], xi[:, :m, :]
        br, bi = xr[:, m:, :], xi[:, m:, :]
        # Twiddles w_p = exp(sign * 2*pi*i * p / n_cur); constant-folded by
        # XLA since n_cur is static.
        ang = sign * 2.0 * math.pi * (jnp.arange(m, dtype=jnp.float32) / n_cur)
        wr = jnp.cos(ang)[None, :, None]
        wi = jnp.sin(ang)[None, :, None]
        sum_r, sum_i = ar + br, ai + bi
        dif_r, dif_i = ar - br, ai - bi
        tw_r = dif_r * wr - dif_i * wi
        tw_i = dif_r * wi + dif_i * wr
        # Stockham interleave: out[p, 2q..] keeps (sum, twiddled) adjacent.
        xr = jnp.stack([sum_r, tw_r], axis=2).reshape(rows, m, 2 * s)
        xi = jnp.stack([sum_i, tw_i], axis=2).reshape(rows, m, 2 * s)
        n_cur, s = m, 2 * s
    xr = xr.reshape(rows, n)
    xi = xi.reshape(rows, n)
    if inverse:
        xr = xr / n
        xi = xi / n
    return xr, xi


def _row_fft_kernel(re_ref, im_ref, out_re_ref, out_im_ref, *, n: int, inverse: bool):
    """Pallas kernel body: FFT every row of the (block_rows, n) tile."""
    xr = re_ref[...]
    xi = im_ref[...]
    yr, yi = _stockham_stages(xr, xi, n, inverse)
    out_re_ref[...] = yr
    out_im_ref[...] = yi


def row_fft(re, im, *, inverse: bool = False, block_rows: int | None = None):
    """Batched 1D FFT over the last axis of split-plane float32 inputs.

    Args:
      re, im: float32 arrays of shape (rows, n), n a power of two.
      inverse: inverse transform (normalised by 1/n).
      block_rows: rows per grid step (defaults to DEFAULT_BLOCK_ROWS,
        clamped to rows; must divide rows).

    Returns:
      (re, im) float32 arrays of shape (rows, n).
    """
    rows, n = re.shape
    if n & (n - 1) or n == 0:
        raise ValueError(f"row length must be a power of two, got {n}")
    if im.shape != re.shape:
        raise ValueError(f"re/im shape mismatch: {re.shape} vs {im.shape}")
    br = min(block_rows or DEFAULT_BLOCK_ROWS, rows)
    if rows % br:
        raise ValueError(f"block_rows {br} must divide rows {rows}")

    kernel = functools.partial(_row_fft_kernel, n=n, inverse=inverse)
    spec = pl.BlockSpec((br, n), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((rows, n), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[out_shape, out_shape],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(re, im)
