"""L1 — Pallas blocked matrix-transpose kernel.

Port of the paper's Appendix A ``hcl_transpose_block`` to the TPU tiling
model: instead of an OpenMP loop over (block_size x block_size) scalar
blocks, the Pallas grid walks (n/b, n/b) tiles, the input BlockSpec maps
grid cell (i, j) to source tile (j, i), and the kernel body transposes one
tile in registers. The HBM<->VMEM schedule expressed by the BlockSpecs is
exactly the paper's cache-blocking intent (block_size=64 there; 64 here).

Used by the full-2D validation model; the rust L3 coordinator has its own
native blocked transpose (rust/src/dft/transpose.rs) for the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64


def _transpose_kernel(in_ref, out_ref):
    out_ref[...] = in_ref[...].T


def transpose(x, *, block: int | None = None):
    """Transpose a square (n, n) float32 matrix with b x b tiling."""
    n, n2 = x.shape
    if n != n2:
        raise ValueError(f"square matrix required, got {x.shape}")
    b = min(block or DEFAULT_BLOCK, n)
    if n % b:
        raise ValueError(f"block {b} must divide n {n}")
    return pl.pallas_call(
        _transpose_kernel,
        grid=(n // b, n // b),
        # read the mirrored source tile, write the natural destination tile
        in_specs=[pl.BlockSpec((b, b), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((b, b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(x)
