"""Pure-jnp correctness oracles for the Pallas FFT kernels.

Two independent references:

* ``dft_rows_naive`` — textbook O(N^2) DFT via an explicit DFT matrix,
  straight from the paper's definition (Section III-A):

      M[k][l] = sum_i sum_j M[i][j] * w^(ki) * w^(lj),  w = exp(-2*pi*i/N)

* ``fft_rows_ref`` / ``dft2d_ref`` — jnp.fft wrappers.

The Pallas kernel is validated against *both* (kernel vs jnp.fft, and
jnp.fft vs naive), so an error in any one implementation is caught.

All entry points use the split re/im float32 representation that the whole
stack (L1 kernel, L2 model, L3 rust runtime) shares: a complex matrix is a
pair of float32 arrays, because the xla-crate literal path and the TPU MXU
story are both real-valued.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def to_complex(re: jnp.ndarray, im: jnp.ndarray) -> jnp.ndarray:
    """Join split planes into a complex64 array."""
    return re.astype(jnp.float32) + 1j * im.astype(jnp.float32)


def from_complex(z: jnp.ndarray):
    """Split a complex array into float32 (re, im) planes."""
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


def dft_matrix(n: int, inverse: bool = False) -> np.ndarray:
    """Dense DFT matrix W[k, j] = exp(-+2*pi*i*k*j/n) as complex128."""
    k = np.arange(n)
    sign = 2.0j if inverse else -2.0j
    w = np.exp(sign * np.pi * np.outer(k, k) / n)
    if inverse:
        w = w / n
    return w


def dft_rows_naive(re: jnp.ndarray, im: jnp.ndarray, inverse: bool = False):
    """O(N^2) row DFT — the paper's Section III-A definition, one axis.

    ``re``/``im`` have shape (rows, n); the transform runs over the last
    axis. Computed in float64 for a tight oracle.
    """
    n = re.shape[-1]
    w = dft_matrix(n, inverse=inverse)
    z = np.asarray(re, dtype=np.float64) + 1j * np.asarray(im, dtype=np.float64)
    out = z @ w.T  # out[r, k] = sum_j z[r, j] * w[k, j]
    return (
        jnp.asarray(out.real, dtype=jnp.float32),
        jnp.asarray(out.imag, dtype=jnp.float32),
    )


def fft_rows_ref(re: jnp.ndarray, im: jnp.ndarray, inverse: bool = False):
    """jnp.fft reference for batched row FFTs over the last axis."""
    z = to_complex(re, im)
    z = jnp.fft.ifft(z, axis=-1) * z.shape[-1] if inverse else jnp.fft.fft(z, axis=-1)
    # note: paper-style unnormalised inverse (scale by n); the kernel's
    # inverse divides by n itself, so tests adjust accordingly.
    return from_complex(z)


def dft2d_ref(re: jnp.ndarray, im: jnp.ndarray):
    """jnp.fft reference for the full 2D-DFT (row-column decomposition)."""
    z = to_complex(re, im)
    return from_complex(jnp.fft.fft2(z))
