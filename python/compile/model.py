"""L2 — JAX compute graph for the 2D-DFT row-column decomposition.

Two entry points, both lowered AOT to HLO text by ``aot.py`` and executed
from the rust L3 coordinator via PJRT:

* ``row_fft_stage`` — the unit the paper's abstract processors execute:
  ``x`` row 1D-FFTs of length ``n`` (Algorithm 6, ``1D_ROW_FFTS_LOCAL``).
  The rust coordinator implements PFFT-LB / PFFT-FPM / PFFT-FPM-PAD by
  dispatching chunks of rows to these executables and transposing
  natively between the two phases.

* ``dft2d`` — the whole row-column decomposition (Section III-A) in one
  graph: row FFTs -> transpose -> row FFTs -> transpose. Used as the
  single-executable baseline ("basic FFT, one group") and as an
  end-to-end numeric cross-check of the rust-orchestrated path.

Complex data is split float32 re/im planes throughout (see kernels/ref.py
for why).
"""

from __future__ import annotations

from .kernels import fft as fft_kernel
from .kernels import transpose as transpose_kernel


def row_fft_stage(re, im, *, inverse: bool = False, block_rows: int | None = None):
    """x row 1D-FFTs of length n over (rows, n) split-plane inputs."""
    return tuple(fft_kernel.row_fft(re, im, inverse=inverse, block_rows=block_rows))


def dft2d(re, im, *, block_rows: int | None = None, transpose_block: int | None = None):
    """Full 2D-DFT of an (n, n) split-plane signal matrix.

    Row-column decomposition exactly as the paper's PFFT-LB steps 1-4,
    fused into one XLA program: the two transposes use the Pallas blocked
    transpose kernel so the whole pipeline exercises both L1 kernels.
    """
    n, n2 = re.shape
    if n != n2:
        raise ValueError(f"square signal matrix required, got {re.shape}")
    # Step 1: 1D-FFTs on rows.
    re, im = fft_kernel.row_fft(re, im, block_rows=block_rows)
    # Step 2: transpose.
    re = transpose_kernel.transpose(re, block=transpose_block)
    im = transpose_kernel.transpose(im, block=transpose_block)
    # Step 3: 1D-FFTs on rows (former columns).
    re, im = fft_kernel.row_fft(re, im, block_rows=block_rows)
    # Step 4: transpose back.
    re = transpose_kernel.transpose(re, block=transpose_block)
    im = transpose_kernel.transpose(im, block=transpose_block)
    return re, im
