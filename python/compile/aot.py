"""AOT lowering: JAX/Pallas model -> HLO text artifacts for the rust runtime.

Python runs ONCE, at build time (``make artifacts``); the rust binary is
self-contained afterwards. Interchange format is HLO **text**, not a
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the published ``xla``
crate) rejects; the text parser reassigns ids and round-trips cleanly.
(See /opt/xla-example/README.md.)

Artifact grid
-------------
* ``row_fft_<rows>x<n>.hlo.txt``   — forward row-FFT stage, (rows, n)
* ``row_ifft_<rows>x<n>.hlo.txt``  — inverse row-FFT stage
* ``full2d_<n>.hlo.txt``           — whole 2D-DFT, (n, n)

Row chunk sizes {1, 8, 32, 128} let the rust coordinator greedily tile any
partition d_i; n covers the power-of-two ladder the real-machine
experiments use. ``manifest.tsv`` (kind, rows, n, file) is the index the
rust side parses — TSV, not JSON, because the offline vendor set has no
serde and a 4-column table does not need one.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

ROW_CHUNKS = (1, 8, 32, 128)
ROW_FFT_SIZES = (128, 256, 512, 1024, 2048)
FULL2D_SIZES = (128, 256, 512)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_row_fft(rows: int, n: int, inverse: bool = False) -> str:
    spec = jax.ShapeDtypeStruct((rows, n), jnp.float32)
    fn = functools.partial(model.row_fft_stage, inverse=inverse)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def lower_full2d(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return to_hlo_text(jax.jit(model.dft2d).lower(spec, spec))


def build(out_dir: str, row_chunks=ROW_CHUNKS, sizes=ROW_FFT_SIZES,
          full2d_sizes=FULL2D_SIZES, verbose: bool = True) -> list[tuple]:
    os.makedirs(out_dir, exist_ok=True)
    manifest: list[tuple] = []  # (kind, rows, n, filename)

    def emit(kind: str, rows: int, n: int, text: str) -> None:
        fname = f"{kind}_{rows}x{n}.hlo.txt" if kind != "full2d" else f"full2d_{n}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest.append((kind, rows, n, fname))
        if verbose:
            print(f"  {fname}: {len(text)} chars")

    for n in sizes:
        for rows in row_chunks:
            if rows > n:
                continue
            emit("row_fft", rows, n, lower_row_fft(rows, n, inverse=False))
            emit("row_ifft", rows, n, lower_row_fft(rows, n, inverse=True))
    for n in full2d_sizes:
        emit("full2d", n, n, lower_full2d(n))

    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("# kind\trows\tn\tfile\n")
        for kind, rows, n, fname in manifest:
            f.write(f"{kind}\t{rows}\t{n}\t{fname}\n")
    if verbose:
        print(f"wrote {len(manifest)} artifacts + manifest.tsv to {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="small grid for CI smoke runs")
    args = ap.parse_args()
    if args.quick:
        build(args.out_dir, row_chunks=(1, 8), sizes=(128,), full2d_sizes=(128,))
    else:
        build(args.out_dir)


if __name__ == "__main__":
    main()
