//! Model-based planning walkthrough on the paper's running example:
//! Intel MKL FFT, N = 24704, two abstract processors of 18 threads
//! (Figures 9-12) — plane sections, the ε-identity test, HPOPTA, and the
//! pad-length selection, on the calibrated virtual testbed.
//!
//! ```sh
//! cargo run --release --example model_based_planning
//! ```

use hclfft::coordinator::pad::{determine_pad_length, PadCost};
use hclfft::coordinator::partition::{balanced, curves_identical, hpopta, predict_makespan};
use hclfft::simulator::fpm::SimTestbed;
use hclfft::simulator::vexec::PAD_WINDOW;
use hclfft::simulator::Package;

fn main() -> Result<(), String> {
    let n = 24_704;
    let tb = SimTestbed::paper_best(Package::Mkl);
    println!(
        "virtual testbed: {} with (p={}, t={})\n",
        tb.model.package.name(),
        tb.cfg.p,
        tb.cfg.t
    );

    // Step 1a — intersect the FPM surfaces with the plane y = N.
    let curves = tb.plane_sections(n);
    println!(
        "plane y = {n}: {} grid points per group (memory-capped)",
        curves[0].len()
    );

    // Step 1b — are the group speed functions identical within 5%?
    let identical = curves_identical(&curves, 0.05);
    println!("ε-identity test (ε = 0.05): {}", if identical { "identical -> POPTA" } else { "heterogeneous -> HPOPTA" });

    // Step 1c/1d — partition.
    let part = hpopta(&curves, n).map_err(|e| e.to_string())?;
    let bal = balanced(tb.cfg.p, n);
    let bal_makespan = predict_makespan(&curves, &bal.d);
    println!("HPOPTA:   d = {:?}, makespan {:.4}", part.d, part.makespan);
    println!("balanced: d = {:?}, makespan {:.4}", bal.d, bal_makespan);
    println!(
        "predicted gain over load-balancing: {:.1}%  (paper's example: d = (11648, 13056))\n",
        100.0 * (1.0 - part.makespan / bal_makespan)
    );

    // PFFT-FPM-PAD Step 2 — pad lengths from the column sections.
    for (i, &di) in part.d.iter().enumerate() {
        let col = tb.column_section(i + 1, di, n, PAD_WINDOW);
        let dec = determine_pad_length(&col, di, n, PadCost::PaperRatio);
        println!(
            "group{}: x = {di} rows -> N_padded = {} (predicted gain {:.1}%)",
            i + 1,
            dec.n_padded,
            100.0 * dec.n_padded_gain()
        );
    }
    println!("(paper's example pads both groups to 24960)");
    Ok(())
}
