//! Serving quickstart: stand up the in-process 2D-DFT service, hit it
//! from concurrent clients, verify a response against the serial oracle,
//! and watch the wisdom store eliminate re-planning.
//!
//! ```sh
//! cargo run --release --example serving
//! ```
//!
//! Request lifecycle: submit → admit → batch → execute → respond.

use hclfft::dft::fft::Direction;
use hclfft::dft::SignalMatrix;
use hclfft::service::wisdom::PlanningConfig;
use hclfft::service::{Dft2dRequest, ServiceBuilder, ServiceConfig};

fn main() -> Result<(), String> {
    // 1. Configure and build the service: 2 workers, batches of up to 8,
    //    p = 2 abstract processors planned by measurement.
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 8,
        planning: PlanningConfig {
            groups: 2,
            threads_per_group: 1,
            rep_scale: 10_000, // demo-fast FPM profiling
            ..PlanningConfig::default()
        },
        ..ServiceConfig::default()
    };
    let svc = ServiceBuilder::new(cfg).native().build();

    // 2. Closed-loop clients: 4 threads × 4 requests over two sizes.
    //    Same-size requests coalesce into shared PFFT dispatches.
    println!("submitting 16 requests from 4 client threads...");
    std::thread::scope(|scope| {
        for c in 0..4u64 {
            let svc = &svc;
            scope.spawn(move || {
                for i in 0..4u64 {
                    let n = if (c + i) % 2 == 0 { 64 } else { 128 };
                    let m = SignalMatrix::random(n, n, c * 10 + i);
                    let resp = svc
                        .submit(Dft2dRequest::forward("native", m))
                        .expect("submit")
                        .wait()
                        .expect("response");
                    assert_eq!(resp.report.d.iter().sum::<usize>(), n);
                }
            });
        }
    });

    // 3. Verify: one more request, checked against the serial dft2d
    //    oracle (the service path is bit-exact).
    let orig = SignalMatrix::random(64, 64, 999);
    let resp = svc
        .submit(Dft2dRequest::forward("native", orig.clone()))
        .map_err(|e| e.to_string())?
        .wait()
        .map_err(|e| e.to_string())?;
    let mut want = orig;
    hclfft::dft::dft2d::dft2d(&mut want, Direction::Forward, 1);
    println!(
        "oracle check: max |service - dft2d| = {:.1e} (bit-exact expected)",
        resp.matrix.max_abs_diff(&want)
    );

    // 4. Stats: note planning_events (one per size, ever) vs wisdom hits
    //    (every later dispatch), and the batch sizes the coalescer found.
    let stats = svc.stats();
    println!("{}", stats.render_table("serving example"));

    // 5. Persist wisdom so the next process starts warm (serve-bench
    //    does this automatically; see `hclfft wisdom` to inspect).
    let path = std::path::PathBuf::from("results/example-wisdom.json");
    svc.save_wisdom(&path)?;
    println!("wisdom saved to {} — a restarted server skips planning", path.display());

    svc.shutdown();
    Ok(())
}
