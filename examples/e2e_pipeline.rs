//! End-to-end driver — proves all three layers compose on a real
//! workload (recorded in EXPERIMENTS.md):
//!
//!   L1 Pallas Stockham kernel → L2 JAX row-FFT model → AOT HLO text →
//!   L3 rust coordinator loading it via PJRT, planning with measured
//!   FPMs (POPTA/HPOPTA), executing PFFT-LB / PFFT-FPM / PFFT-FPM-PAD,
//!   and verifying numerics against two independent oracles.
//!
//! Workload: batched 2D-DFT requests over the artifact grid (a small
//! "serving" trace: mixed sizes, mixed batch shapes), reporting
//! per-request latency and aggregate throughput in the paper's MFLOPs.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::path::Path;
use std::time::Instant;

use hclfft::coordinator::engine::{NativeEngine, RowFftEngine};
use hclfft::coordinator::group::GroupConfig;
use hclfft::coordinator::pad::{pads_for_distribution, PadCost};
use hclfft::coordinator::pfft::{pfft_fpm, pfft_fpm_pad, pfft_lb, plan_partition};
use hclfft::dft::{naive_dft2d, SignalMatrix};
use hclfft::profiler::build_plane;
use hclfft::runtime::PjrtRowFftEngine;
use hclfft::stats::harness::fft2d_flops;

fn main() -> Result<(), String> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.tsv").exists() {
        return Err("artifacts/ missing — run `make artifacts` first".into());
    }

    println!("== e2e: L1 Pallas -> L2 JAX -> AOT HLO -> L3 rust/PJRT ==\n");
    let engine = PjrtRowFftEngine::load(artifacts).map_err(|e| e.to_string())?;
    let lengths = engine.supported_lengths().unwrap();
    println!("artifact grid row lengths: {lengths:?}");

    // ---- Phase 1: profile the PJRT engine & plan per size -------------
    let cfg = GroupConfig::new(2, 1);
    let mut plans = Vec::new();
    for &n in lengths.iter().filter(|&&n| n <= 512) {
        let xs: Vec<usize> = (1..=4).map(|k| k * n / 4).collect();
        let t0 = Instant::now();
        let fpms = build_plane(&engine, cfg, xs, n, 10_000);
        let model = hclfft::model::StaticModel::new(fpms);
        let part = plan_partition(&model, n, 0.05).map_err(|e| e.to_string())?;
        let pads = pads_for_distribution(&model, &part.d, n, usize::MAX, PadCost::PaperRatio);
        println!(
            "plan n={n}: d = {:?} ({:?}), pads = {:?} [profiled+planned in {:.2}s]",
            part.d,
            part.algorithm,
            pads.iter().map(|p| p.n_padded).collect::<Vec<_>>(),
            t0.elapsed().as_secs_f64()
        );
        plans.push((n, part, pads));
    }

    // ---- Phase 2: serve a mixed-size request trace ---------------------
    let trace: Vec<usize> = plans
        .iter()
        .cycle()
        .take(plans.len() * 4)
        .map(|(n, _, _)| *n)
        .collect();
    let mut total_flops = 0.0f64;
    let mut total_time = 0.0f64;
    let mut latencies = Vec::new();
    for (req, &n) in trace.iter().enumerate() {
        let (_, part, pads) = plans.iter().find(|(pn, _, _)| *pn == n).unwrap();
        let mut m = SignalMatrix::random(n, n, req as u64);
        let t0 = Instant::now();
        pfft_fpm_pad(&engine, &mut m, &part.d, pads, cfg.t, 64).map_err(|e| e.to_string())?;
        let dt = t0.elapsed().as_secs_f64();
        latencies.push(dt);
        total_flops += fft2d_flops(n);
        total_time += dt;
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[((latencies.len() * 99) / 100).min(latencies.len() - 1)];
    println!(
        "\nserved {} requests: {:.1} MFLOPs aggregate, p50 {:.2} ms, p99 {:.2} ms",
        trace.len(),
        total_flops / total_time / 1e6,
        p50 * 1e3,
        p99 * 1e3
    );

    // ---- Phase 3: verify the stack against two oracles -----------------
    let n = plans[0].0;
    let signal = SignalMatrix::random(n, n, 7);
    let (_, part, _) = &plans[0];

    let mut via_pjrt = signal.clone();
    pfft_fpm(&engine, &mut via_pjrt, &part.d, cfg.t, 64).map_err(|e| e.to_string())?;

    let mut via_native = signal.clone();
    pfft_lb(&NativeEngine, &mut via_native, cfg, 64).map_err(|e| e.to_string())?;

    let naive = naive_dft2d(&signal);
    let err_pjrt = via_pjrt.max_abs_diff(&naive) / naive.norm().max(1.0);
    let err_native = via_native.max_abs_diff(&naive) / naive.norm().max(1.0);
    println!("\nverification at n={n}:");
    println!("  PJRT (f32 artifacts) vs naive oracle: rel err {err_pjrt:.2e}");
    println!("  native (f64)         vs naive oracle: rel err {err_native:.2e}");
    if err_pjrt > 1e-4 || err_native > 1e-10 {
        return Err("verification FAILED".into());
    }

    // ---- Phase 4: compare coordinator algorithms on the PJRT engine ----
    println!("\nalgorithm comparison on PJRT engine (n = 512, mean of 5):");
    let n = 512;
    let (_, part, pads) = plans.iter().find(|(pn, _, _)| *pn == 512).unwrap();
    for (label, runner) in [
        ("basic (1 group)", 0usize),
        ("PFFT-LB", 1),
        ("PFFT-FPM", 2),
        ("PFFT-FPM-PAD", 3),
    ] {
        let mut secs = 0.0;
        const REPS: usize = 5;
        for rep in 0..REPS {
            let mut m = SignalMatrix::random(n, n, rep as u64);
            let t0 = Instant::now();
            match runner {
                0 => pfft_lb(&engine, &mut m, GroupConfig::new(1, 2), 64),
                1 => pfft_lb(&engine, &mut m, cfg, 64),
                2 => pfft_fpm(&engine, &mut m, &part.d, cfg.t, 64),
                _ => pfft_fpm_pad(&engine, &mut m, &part.d, pads, cfg.t, 64),
            }
            .map_err(|e| e.to_string())?;
            secs += t0.elapsed().as_secs_f64();
        }
        let mean = secs / REPS as f64;
        println!(
            "  {label:<16} {:.2} ms  ({:.1} MFLOPs)",
            mean * 1e3,
            fft2d_flops(n) / mean / 1e6
        );
    }

    println!("\ne2e pipeline OK — all layers compose.");
    Ok(())
}
