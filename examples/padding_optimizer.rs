//! Padding as a performance lever: sweep the virtual campaign and report
//! where PFFT-FPM-PAD beats PFFT-FPM, by how much, and which pad lengths
//! get chosen — the mechanism behind Figures 16/21.
//!
//! Also demonstrates the exact-flops ablation of the pad cost model
//! (DESIGN.md §Perf).
//!
//! ```sh
//! cargo run --release --example padding_optimizer
//! ```

use hclfft::coordinator::pad::{determine_pad_length, PadCost};
use hclfft::simulator::fpm::SimTestbed;
use hclfft::simulator::vexec::{simulate_size, PAD_WINDOW};
use hclfft::simulator::Package;

fn main() {
    let tb = SimTestbed::paper_best(Package::Mkl);
    let sizes: Vec<usize> = (0..30).map(|k| 10_048 + 1_152 * k).collect();

    println!("{:>7} {:>10} {:>10} {:>9} {:>11}", "N", "t_fpm(s)", "t_pad(s)", "gain", "pads");
    let mut padded_count = 0usize;
    let mut gain_sum = 0.0f64;
    for &n in &sizes {
        let p = simulate_size(&tb, n);
        let gain = p.t_fpm / p.t_pad;
        let padded = p.pads.iter().any(|&v| v != n);
        if padded {
            padded_count += 1;
            gain_sum += gain;
        }
        println!(
            "{:>7} {:>10.4} {:>10.4} {:>8.2}x {:>11}",
            n,
            p.t_fpm,
            p.t_pad,
            gain,
            if padded { format!("{:?}", p.pads) } else { "none".to_string() }
        );
    }
    println!(
        "\npadding chosen on {padded_count}/{} sizes; mean gain when padded {:.2}x",
        sizes.len(),
        if padded_count > 0 { gain_sum / padded_count as f64 } else { 1.0 }
    );

    // ablation: paper-ratio vs exact-flops cost on one size
    let n = 24_704;
    let curves = tb.plane_sections(n);
    let part = hclfft::coordinator::partition::hpopta(&curves, n).unwrap();
    let col = tb.column_section(1, part.d[0], n, PAD_WINDOW);
    let paper = determine_pad_length(&col, part.d[0], n, PadCost::PaperRatio);
    let exact = determine_pad_length(&col, part.d[0], n, PadCost::ExactFlops);
    println!(
        "\ncost-model ablation at N = {n}: paper-ratio pads to {}, exact-flops to {}",
        paper.n_padded, exact.n_padded
    );
}
