//! Domain application: FFT-based 2D convolution (Gaussian blur) — the
//! kind of image/signal-processing workload the paper's introduction
//! motivates, run through the model-based coordinator.
//!
//! Convolution theorem: blur = IFFT2( FFT2(image) ⊙ FFT2(kernel) ).
//! Both forward transforms and the inverse run through PFFT-FPM, so the
//! whole application sits on the paper's optimized path. Verified
//! against direct spatial convolution.
//!
//! ```sh
//! cargo run --release --example convolution_filter
//! ```

use hclfft::coordinator::engine::{NativeEngine, RowFftEngine};
use hclfft::dft::fft::Direction;
use hclfft::dft::transpose::transpose_in_place_parallel;
use hclfft::dft::SignalMatrix;

/// 2D-DFT through the engine in a chosen direction (rows→T→rows→T).
fn dft2d_via_engine(engine: &dyn RowFftEngine, m: &mut SignalMatrix, dir: Direction) {
    let n = m.rows;
    engine.fft_rows(&mut m.re, &mut m.im, n, n, dir, 2).unwrap();
    transpose_in_place_parallel(m, 64, 2);
    engine.fft_rows(&mut m.re, &mut m.im, n, n, dir, 2).unwrap();
    transpose_in_place_parallel(m, 64, 2);
}

fn main() {
    let n = 128;

    // synthetic "image": a bright square + gradient background
    let mut image = SignalMatrix::zeros(n, n);
    for r in 0..n {
        for c in 0..n {
            let mut v = 0.2 * (r + c) as f64 / (2 * n) as f64;
            if (40..60).contains(&r) && (40..60).contains(&c) {
                v += 1.0;
            }
            image.set(r, c, v, 0.0);
        }
    }

    // circularly-wrapped Gaussian kernel, normalized
    let sigma = 2.0f64;
    let mut kernel = SignalMatrix::zeros(n, n);
    let mut total = 0.0;
    for r in 0..n {
        for c in 0..n {
            let dr = ((r + n / 2) % n) as f64 - (n / 2) as f64;
            let dc = ((c + n / 2) % n) as f64 - (n / 2) as f64;
            let v = (-(dr * dr + dc * dc) / (2.0 * sigma * sigma)).exp();
            kernel.set(r, c, v, 0.0);
            total += v;
        }
    }
    for v in kernel.re.iter_mut() {
        *v /= total;
    }

    // FFT-based convolution on the coordinator path
    let t0 = std::time::Instant::now();
    let mut fi = image.clone();
    let mut fk = kernel.clone();
    dft2d_via_engine(&NativeEngine, &mut fi, Direction::Forward);
    dft2d_via_engine(&NativeEngine, &mut fk, Direction::Forward);
    // pointwise spectral product
    let mut prod = SignalMatrix::zeros(n, n);
    for i in 0..n * n {
        prod.re[i] = fi.re[i] * fk.re[i] - fi.im[i] * fk.im[i];
        prod.im[i] = fi.re[i] * fk.im[i] + fi.im[i] * fk.re[i];
    }
    dft2d_via_engine(&NativeEngine, &mut prod, Direction::Inverse);
    let t_fft = t0.elapsed().as_secs_f64();

    // direct spatial convolution on a probe set (full direct is O(n^4))
    let probes = [(50usize, 50usize), (10, 100), (64, 64), (0, 0)];
    let mut max_err = 0.0f64;
    for &(pr, pc) in &probes {
        let mut acc = 0.0;
        for r in 0..n {
            for c in 0..n {
                let (iv, _) = image.get(r, c);
                let (kv, _) = kernel.get((pr + n - r) % n, (pc + n - c) % n);
                acc += iv * kv;
            }
        }
        let (got, _) = prod.get(pr, pc);
        max_err = max_err.max((got - acc).abs());
    }

    println!("FFT-based 128x128 Gaussian blur via the coordinator: {:.2} ms", t_fft * 1e3);
    println!("verified against direct convolution at {} probes: max err {max_err:.2e}", probes.len());
    assert!(max_err < 1e-9, "convolution mismatch");
    // blur sanity: the square's edge is smoothed (center keeps energy,
    // corner far from the square stays near background)
    let (center, _) = prod.get(50, 50);
    let (edge, _) = prod.get(39, 50);
    let (bg, _) = prod.get(100, 10);
    println!("blur profile: center {center:.3} > edge {edge:.3} > background {bg:.3}");
    assert!(center > edge && edge > bg);
    println!("convolution_filter OK");
}
