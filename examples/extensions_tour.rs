//! Tour of the repo's extensions beyond the paper's 2D experiments —
//! the §VII future-work items and baselines, all runnable:
//!
//! 1. PFFT-FPM-3D (slab-decomposed 3D-DFT) — measured + verified,
//! 2. the distributed-cluster model (homogeneous + heterogeneous),
//! 3. the time/energy Pareto front (bi-objective partitioning),
//! 4. the dynamic work-stealing baseline, real execution.
//!
//! ```sh
//! cargo run --release --example extensions_tour
//! ```

use hclfft::coordinator::dynamic::pfft_dynamic;
use hclfft::coordinator::energy::pareto_front;
use hclfft::coordinator::engine::NativeEngine;
use hclfft::coordinator::fpm::Curve;
use hclfft::coordinator::pfft3d::pfft_fpm_3d;
use hclfft::dft::dft3d::{dft3d, SignalCube};
use hclfft::dft::fft::Direction;
use hclfft::dft::SignalMatrix;
use hclfft::simulator::cluster::{strong_scaling, VirtualCluster};
use hclfft::simulator::fpm::SimTestbed;
use hclfft::simulator::Package;

fn main() -> Result<(), String> {
    // ---- 1. 3D-DFT ----------------------------------------------------
    println!("== 1. PFFT-FPM-3D (paper §VII future work) ==");
    let n = 32;
    let orig = SignalCube::random(n, 1);
    let mut slab = orig.clone();
    let t0 = std::time::Instant::now();
    pfft_fpm_3d(&NativeEngine, &mut slab, &[12, 20], 1, 16).map_err(|e| e.to_string())?;
    let t_slab = t0.elapsed().as_secs_f64();
    let mut serial = orig.clone();
    dft3d(&mut serial, Direction::Forward, 1);
    let err = slab.max_abs_diff(&serial) / serial.norm().max(1.0);
    println!("  {n}^3 cube, imbalanced slabs (12, 20): {:.1} ms, rel err {err:.2e}\n", t_slab * 1e3);

    // ---- 2. cluster scaling -------------------------------------------
    println!("== 2. distributed clusters (virtual, N = 24704, MKL nodes) ==");
    for pt in strong_scaling(Package::Mkl, 24_704, &[1, 2, 4, 8], 0.0) {
        println!(
            "  homogeneous {} node(s): t = {:.3}s, speedup {:.2}x",
            pt.nodes, pt.t_fpm, pt.speedup_vs_single
        );
    }
    let het = VirtualCluster::heterogeneous(Package::Mkl, 4, 0.4);
    let (t_fpm, d) = het.dft2d_time_fpm(24_704).map_err(|e| e.to_string())?;
    let t_bal = het.dft2d_time_balanced(24_704);
    println!(
        "  heterogeneous 4 nodes (40% skew): HPOPTA d = {d:?} -> {:.3}s vs balanced {:.3}s ({:.0}% faster)\n",
        t_fpm,
        t_bal,
        100.0 * (1.0 - t_fpm / t_bal)
    );

    // ---- 3. energy Pareto front ----------------------------------------
    println!("== 3. time/energy Pareto front (bi-objective partitioning) ==");
    let tb = SimTestbed::paper_best(Package::Mkl);
    let n2d = 12_800;
    let speed = tb.plane_sections(n2d);
    let energy: Vec<Curve> = speed
        .iter()
        .map(|c| {
            let joules: Vec<f64> =
                c.xs.iter()
                    .zip(&c.speeds)
                    .map(|(&x, &s)| x as f64 / s * (120.0 + 90.0 * x as f64 / n2d as f64))
                    .collect();
            Curve::new(c.xs.clone(), joules)
        })
        .collect();
    let front = pareto_front(&speed, &energy, n2d - n2d % 128).map_err(|e| e.to_string())?;
    println!("  {} Pareto points; extremes:", front.len());
    if let (Some(fast), Some(frugal)) = (front.first(), front.last()) {
        println!("    fastest: t = {:.3}, E = {:.1}", fast.makespan, fast.energy);
        println!(
            "    most frugal: t = {:.3} (+{:.0}%), E = {:.1} (−{:.0}%)\n",
            frugal.makespan,
            100.0 * (frugal.makespan / fast.makespan - 1.0),
            frugal.energy,
            100.0 * (1.0 - frugal.energy / fast.energy)
        );
    }

    // ---- 4. dynamic baseline, real execution ---------------------------
    println!("== 4. dynamic work-stealing baseline (real, native engine) ==");
    let n = 128;
    let orig2 = SignalMatrix::random(n, n, 2);
    let mut m = orig2.clone();
    let rep = pfft_dynamic(&NativeEngine, &mut m, 2, 1, 16, 64).map_err(|e| e.to_string())?;
    let mut want = orig2.clone();
    hclfft::dft::dft2d::dft2d(&mut want, Direction::Forward, 1);
    let err = m.max_abs_diff(&want) / want.norm().max(1.0);
    println!(
        "  N={n}: {:.1} ms, chunks stolen per group {:?}, rel err {err:.2e}",
        rep.elapsed_s * 1e3,
        rep.chunks_per_group
    );
    println!("\nextensions tour OK");
    Ok(())
}
