//! Quickstart: compute a 2D-DFT with the model-based coordinator in
//! five steps — profile, plan, execute, verify, report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hclfft::coordinator::engine::NativeEngine;
use hclfft::coordinator::group::GroupConfig;
use hclfft::coordinator::pfft::{pfft_fpm, pfft_lb, plan_partition};
use hclfft::dft::{naive_dft2d, SignalMatrix};
use hclfft::model::StaticModel;
use hclfft::profiler::build_plane;

fn main() -> Result<(), String> {
    let n = 256; // signal matrix is n x n complex
    let cfg = GroupConfig::new(2, 1); // p = 2 abstract processors, t = 1

    // 1. Profile: build the speed functions (FPMs) of the two abstract
    //    processors on the plane y = n, with the paper's Student's-t
    //    measurement loop (rep counts scaled down for a demo).
    println!("profiling {} on the y = {n} plane...", cfg);
    let xs: Vec<usize> = (1..=8).map(|k| k * n / 8).collect();
    let fpms = build_plane(&NativeEngine, cfg, xs, n, 10_000);

    // 2. Plan: ε-identity test, then POPTA (identical) or HPOPTA
    //    (heterogeneous) — PFFT-FPM Step 1. Planning consumes the
    //    surfaces through the unified PerfModel trait.
    let part = plan_partition(&StaticModel::new(fpms), n, 0.05).map_err(|e| e.to_string())?;
    println!("planned distribution d = {:?} ({:?})", part.d, part.algorithm);

    // 3. Execute PFFT-FPM on a random complex signal matrix.
    let signal = SignalMatrix::random(n, n, 42);
    let mut out = signal.clone();
    let report =
        pfft_fpm(&NativeEngine, &mut out, &part.d, cfg.t, 64).map_err(|e| e.to_string())?;
    println!("PFFT-FPM executed in {:.3} ms", report.elapsed_s * 1e3);

    // 4. Verify against the O(N^2)-per-row naive oracle.
    let want = naive_dft2d(&signal);
    let rel_err = out.max_abs_diff(&want) / want.norm().max(1.0);
    println!("verified vs naive 2D-DFT: rel err {rel_err:.2e}");
    assert!(rel_err < 1e-9);

    // 5. Compare with the balanced baseline (PFFT-LB).
    let mut lb_out = signal.clone();
    let lb = pfft_lb(&NativeEngine, &mut lb_out, cfg, 64).map_err(|e| e.to_string())?;
    println!(
        "PFFT-LB (balanced) took {:.3} ms -> speedup {:.2}x",
        lb.elapsed_s * 1e3,
        lb.elapsed_s / report.elapsed_s
    );
    Ok(())
}
